//! The unified engine API — the single front door to the stack
//! (DESIGN.md §12).
//!
//! Everything that used to be wired by hand in `coordinator`, `figures`,
//! the benches and `main.rs` (engine construction, `PlanCache` sharing,
//! `ExecPool` sizing) now flows through three pieces:
//!
//! * [`Backend`] — an object-safe execution backend: the `dyn`-friendly
//!   rework of [`crate::sched::GemmEngine`] with a `name()` /
//!   [`Backend::capabilities`] surface and runtime-programmable knobs
//!   ([`Backend::apply`]) for the per-call digital/analog boundary,
//!   noise seed and OSE thresholds — the paper's dynamic precision
//!   configuration as a first-class runtime decision instead of a type
//!   parameter;
//! * [`BackendRegistry`] — string-selectable backend factories.  The
//!   builtin registry carries `macro-hybrid` (the mode-configurable
//!   native simulator), `macro-dcim` / `macro-acim` (the all-digital and
//!   all-analog baselines pinned by name), `macro-fleet` (K simulated
//!   macros with sharded placement, split-K transfer accounting and
//!   CIMPool weight pooling — `sched::fleet`) and `pjrt` (the AOT
//!   artifact runtime; stub-aware — registered but unavailable without
//!   the `pjrt` feature).  Future backends (GPU, remote macro) land as
//!   registry entries, not refactors;
//! * [`Engine`] / [`EngineBuilder`] — owns the graph, the shared
//!   weight-stationary [`PlanCache`] and the tile [`ExecPool`], and
//!   hands out backend instances that all share both:
//!
//! ```no_run
//! # use osa_hcim::engine::Engine;
//! # use osa_hcim::nn::QGraph;
//! # use std::sync::Arc;
//! let engine = Engine::builder()
//!     .graph(Arc::new(QGraph::synthetic()))
//!     .backend("macro-hybrid")
//!     .threads(4)
//!     .build()?;
//! let mut exec = engine.executor()?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The typed [`InferRequest`] / [`InferOptions`] / [`InferResponse`]
//! structs are shared verbatim by in-process callers
//! (`coordinator::Server::submit_request`) and the versioned
//! `POST /v2/infer` HTTP route (`serve::gateway`), so the wire surface
//! and the library surface can never drift apart.

use crate::config::{CimMode, SystemConfig};
use crate::device::{DeviceModel, DeviceParams};
use crate::energy::hierarchy::{MemoryHierarchy, MODEL_HIERARCHY, NUM_LEVELS};
use crate::macrosim::ose::Ose;
use crate::nn::{Executor, QGraph};
use crate::sched::exec::ExecPool;
use crate::sched::fleet::{self, FleetGemm};
use crate::sched::plan::{FleetDims, PlacementMode, PlanCache, PlanCacheStats};
use crate::sched::{GemmEngine, GemmResult, MacroGemm};
use crate::serve::qos::Tier;
use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::Duration;

// ------------------------------------------------------------------ Backend

/// The analog device statistics a backend executes under — part of
/// [`Capabilities`] so routing and introspection (`/v1/version`,
/// `/healthz`, `GET /v2/device`) can see which silicon corner is live
/// (DESIGN.md §16).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceCaps {
    /// Device model registry name (`device::MODEL_NAMES`).
    pub model: &'static str,
    /// Model strength (conversion-noise or column-variation sigma).
    pub sigma: f64,
    /// Operation-unit group size (0 = full-width conversions).
    pub s_ou: usize,
}

/// What a backend can do — used for routing decisions (e.g. the
/// coordinator only programs OSE thresholds into backends that report
/// `programmable_thresholds`) and for `/v1/version` + `/healthz` +
/// `GET /v2/topology` introspection.  Structured around the fleet
/// topology (`macros` x `residency_bytes`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Capabilities {
    /// The backend can actually execute in this build (the `pjrt` entry
    /// is registered but unavailable without the `pjrt` feature).
    pub available: bool,
    /// The CIM datapath mode this instance runs.
    pub mode: CimMode,
    /// Simulated macros this backend executes on: 1 for the single-macro
    /// backends, the fleet size K for `macro-fleet`.
    pub macros: usize,
    /// Weight-stationary SRAM residency budget *per macro*, in bytes
    /// (`residency_tiles` x packed-tile bytes on the fleet; one packed
    /// tile on single-macro backends).
    pub residency_bytes: u64,
    /// OSE threshold registers exist and can be re-programmed per call
    /// (the OSA datapath).
    pub programmable_thresholds: bool,
    /// A fixed digital/analog boundary override (`fixed_b`) is
    /// meaningful (HCIM-style hybrid modes).
    pub hybrid_boundary: bool,
    /// CIMPool-style weight-tile pooling is active as the spill strategy
    /// when a model exceeds aggregate residency (fleet `auto` placement).
    pub pooling: bool,
    /// The energy cost model this backend prices with: `"compact"`
    /// (per-op constants) or `"hierarchy"` (dataflow-priced memory
    /// levels, `[hardware] model`) — DESIGN.md §15.
    pub cost_model: &'static str,
    /// Memory levels the cost model resolves movement against
    /// (`energy::hierarchy::NUM_LEVELS` under `"hierarchy"`, 0 under
    /// `"compact"` where movement is folded into the op constants).
    pub memory_levels: usize,
    /// The analog device model this backend's conversions run through.
    pub device: DeviceCaps,
    /// One-line human description.
    pub description: &'static str,
}

/// Per-call knob overrides — the dynamic D/A boundary of the paper as a
/// runtime decision.  `None` leaves the backend's current value alone.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BackendKnobs {
    /// Base seed of the per-unit ADC noise streams.
    pub noise_seed: Option<u64>,
    /// Fixed digital/analog boundary (HCIM mode).
    pub fixed_b: Option<i32>,
    /// OSE threshold registers (ascending; OSA mode).
    pub thresholds: Option<Vec<i32>>,
    /// Fleet placement mode (`auto` / `replicate` / `resident`);
    /// meaningful on `macro-fleet`, ignored by single-macro backends.
    pub placement: Option<String>,
}

/// Object-safe execution backend: the `dyn`-friendly face of
/// [`GemmEngine`].  All methods return concrete types so
/// `Box<dyn Backend>` works everywhere a monomorphized engine used to,
/// including inside [`crate::nn::Executor`] (via the blanket
/// [`GemmEngine`] impl below).
pub trait Backend: Send {
    /// `a`: `[m, k]` uint8-as-i32 row-major; `w`: `[n, k]` int8-as-i32.
    fn gemm(
        &mut self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
        layer_idx: u64,
    ) -> Result<GemmResult>;

    /// Build (and cache) the layer's execution plan ahead of time.
    fn prepare(&mut self, w: &[i32], n: usize, k: usize, layer_idx: u64) -> Result<()>;

    /// The registry name this backend was built under (`macro-hybrid`,
    /// `macro-dcim`, ...) — the string a client selects it by.
    fn name(&self) -> &str;

    /// Capability surface for routing and introspection.
    fn capabilities(&self) -> Capabilities;

    /// Re-program the backend's runtime knobs.  Implementations must be
    /// idempotent (applying the current values is a cheap no-op) because
    /// the coordinator re-applies per batch.
    fn apply(&mut self, knobs: &BackendKnobs) -> Result<()>;

    /// Current OSE thresholds, when the backend has threshold registers.
    fn thresholds(&self) -> Option<Vec<i32>>;

    /// A fresh, independently-owned instance sharing the same plan
    /// cache and pool (one per coordinator worker).
    fn clone_backend(&self) -> Result<Box<dyn Backend>>;
}

/// `Box<dyn Backend>` drives everything a monomorphized [`GemmEngine`]
/// drives — this is what lets `nn::Executor<Box<dyn Backend>>` replace
/// `nn::Executor<MacroGemm>` without touching the executor.
impl GemmEngine for Box<dyn Backend> {
    fn gemm(
        &mut self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
        layer_idx: u64,
    ) -> Result<GemmResult> {
        (**self).gemm(a, m, k, w, n, layer_idx)
    }

    fn prepare(&mut self, w: &[i32], n: usize, k: usize, layer_idx: u64) -> Result<()> {
        (**self).prepare(w, n, k, layer_idx)
    }

    fn name(&self) -> &str {
        (**self).name()
    }
}

// ----------------------------------------------------------- typed errors

/// Typed backend-selection failures.  Carried through `anyhow` so the
/// CLI prints them directly; the gateway maps the same conditions
/// (re-detected via [`BackendRegistry::get`]) onto typed 400s.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendError {
    /// The requested name is not in the registry.
    Unknown { requested: String, registered: Vec<String> },
    /// Registered, but cannot run in this build.
    Unavailable { name: String, reason: String },
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendError::Unknown { requested, registered } => write!(
                f,
                "unknown backend {requested:?} (registered: {})",
                registered.join(", ")
            ),
            BackendError::Unavailable { name, reason } => {
                write!(f, "backend {name:?} is registered but unavailable: {reason}")
            }
        }
    }
}

impl std::error::Error for BackendError {}

// ------------------------------------------------------------- registry

/// Everything a backend factory needs: the resolved config plus the
/// engine's shared plan cache and tile pool.
pub struct BackendCtx<'a> {
    pub cfg: &'a SystemConfig,
    pub plans: Arc<PlanCache>,
    pub pool: Arc<ExecPool>,
}

/// A backend factory function (plain `fn` so the registry stays
/// `Clone` + `Send` + `Sync` for free).
pub type BackendFactory = fn(&BackendCtx) -> Result<Box<dyn Backend>>;

/// One registry entry.
#[derive(Clone)]
pub struct BackendSpec {
    pub name: &'static str,
    pub description: &'static str,
    /// Whether this build can actually construct the backend (the
    /// `pjrt` entry is registered either way so error messages can say
    /// *why* it is missing instead of "unknown backend").
    pub available: bool,
    pub factory: BackendFactory,
}

/// String-selectable backend factories.  Registration order is the
/// listing order shown in errors and `/v1/version`.
#[derive(Clone, Default)]
pub struct BackendRegistry {
    entries: Vec<BackendSpec>,
}

impl BackendRegistry {
    /// An empty registry (extension point for embedders).
    pub fn new() -> Self {
        Self::default()
    }

    /// The builtin set: `macro-hybrid`, `macro-dcim`, `macro-acim`,
    /// `macro-fleet`, `pjrt`.
    pub fn builtin() -> Self {
        let mut r = Self::new();
        r.register(BackendSpec {
            name: "macro-hybrid",
            description: "native cycle-level macro simulator in the configured CIM mode \
                          (osa/hcim/pg/drq via [cim] mode)",
            available: true,
            factory: build_macro_hybrid,
        });
        r.register(BackendSpec {
            name: "macro-dcim",
            description: "native simulator pinned to the all-digital (loss-free) baseline",
            available: true,
            factory: build_macro_dcim,
        });
        r.register(BackendSpec {
            name: "macro-acim",
            description: "native simulator pinned to the full-analog baseline",
            available: true,
            factory: build_macro_acim,
        });
        r.register(BackendSpec {
            name: "macro-fleet",
            description: "K simulated macros: sharded placement, split-K transfer \
                          accounting, CIMPool weight pooling ([fleet] / EngineBuilder::fleet)",
            available: true,
            factory: build_macro_fleet,
        });
        r.register(BackendSpec {
            name: "pjrt",
            description: if cfg!(feature = "pjrt") {
                "AOT PJRT artifact runtime (Pallas tile kernels)"
            } else {
                "AOT PJRT artifact runtime — built without the `pjrt` feature"
            },
            available: cfg!(feature = "pjrt"),
            factory: build_pjrt,
        });
        r
    }

    /// Add (or replace, by name) an entry.
    pub fn register(&mut self, spec: BackendSpec) {
        if let Some(slot) = self.entries.iter_mut().find(|e| e.name == spec.name) {
            *slot = spec;
        } else {
            self.entries.push(spec);
        }
    }

    pub fn get(&self, name: &str) -> Option<&BackendSpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    /// All entries, in registration order.
    pub fn specs(&self) -> &[BackendSpec] {
        &self.entries
    }

    /// Build a backend by name.  Unknown names produce a typed
    /// [`BackendError::Unknown`] listing every registered backend.
    pub fn build(&self, name: &str, ctx: &BackendCtx) -> Result<Box<dyn Backend>> {
        let Some(spec) = self.get(name) else {
            return Err(anyhow::Error::new(BackendError::Unknown {
                requested: name.to_string(),
                registered: self.names().iter().map(|s| s.to_string()).collect(),
            }));
        };
        (spec.factory)(ctx)
    }
}

// ------------------------------------------------- native backend + factories

/// The native cycle-level simulator behind a registry name.
#[derive(Clone)]
struct NativeBackend {
    reg_name: &'static str,
    inner: MacroGemm,
}

impl Backend for NativeBackend {
    fn gemm(
        &mut self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
        layer_idx: u64,
    ) -> Result<GemmResult> {
        self.inner.gemm(a, m, k, w, n, layer_idx)
    }

    fn prepare(&mut self, w: &[i32], n: usize, k: usize, layer_idx: u64) -> Result<()> {
        self.inner.prepare(w, n, k, layer_idx)
    }

    fn name(&self) -> &str {
        self.reg_name
    }

    fn capabilities(&self) -> Capabilities {
        let mode = self.inner.mode;
        let cost_model = self.inner.cost_model();
        Capabilities {
            available: true,
            mode,
            macros: 1,
            residency_bytes: fleet::tile_bytes(&self.inner.spec),
            programmable_thresholds: mode == CimMode::Osa,
            hybrid_boundary: matches!(mode, CimMode::Hcim | CimMode::Osa),
            pooling: false,
            cost_model,
            memory_levels: if cost_model == MODEL_HIERARCHY { NUM_LEVELS } else { 0 },
            device: device_caps(self.inner.device()),
            description: "native cycle-level macro simulator",
        }
    }

    fn apply(&mut self, knobs: &BackendKnobs) -> Result<()> {
        if let Some(seed) = knobs.noise_seed {
            self.inner.noise_seed = seed;
        }
        if let Some(b) = knobs.fixed_b {
            self.inner.fixed_b = b;
        }
        if let Some(ts) = &knobs.thresholds {
            // rebuilding the OSE is the only non-trivial knob: skip it
            // when the registers already hold these values (the
            // coordinator re-applies per batch)
            if ts.as_slice() != self.inner.ose.thresholds() {
                self.inner.ose = Ose::with_default_candidates(ts.clone())?;
            }
        }
        Ok(())
    }

    fn thresholds(&self) -> Option<Vec<i32>> {
        Some(self.inner.ose.thresholds().to_vec())
    }

    fn clone_backend(&self) -> Result<Box<dyn Backend>> {
        Ok(Box::new(self.clone()))
    }
}

/// The `[hardware]` stack to price movement against, or `None` under
/// the (default, bit-compatible) compact model.
fn hierarchy_of(cfg: &SystemConfig) -> Option<Arc<MemoryHierarchy>> {
    cfg.hierarchy_model().then(|| Arc::new(cfg.hardware.clone()))
}

/// The `[device]` model the config asks for.  The default
/// (`gaussian-thermal`, sigma inherited from `cim.sigma_code`, no ADC
/// error, no grouping) is the bit-preserved legacy convention.
pub fn device_of(cfg: &SystemConfig) -> Result<Arc<dyn DeviceModel>> {
    let params = DeviceParams {
        sigma: cfg.device_sigma.unwrap_or(cfg.spec.sigma_code),
        s_ou: cfg.device_s_ou,
        adc_offset: cfg.device_adc_offset as f32,
        adc_gain: cfg.device_adc_gain as f32,
    };
    crate::device::build(&cfg.device_model, params)
}

/// The device block of a backend's capability surface.
fn device_caps(device: &Arc<dyn DeviceModel>) -> DeviceCaps {
    let p = device.params();
    DeviceCaps { model: device.name(), sigma: p.sigma, s_ou: p.s_ou }
}

fn build_native(
    ctx: &BackendCtx,
    reg_name: &'static str,
    mode: CimMode,
) -> Result<Box<dyn Backend>> {
    let gemm = MacroGemm::new(
        mode,
        ctx.cfg.spec,
        ctx.cfg.fixed_b,
        ctx.cfg.thresholds.clone(),
        ctx.cfg.noise_seed,
    )?
    .with_plan_cache(ctx.plans.clone())
    .with_pool(ctx.pool.clone())
    .with_hierarchy(hierarchy_of(ctx.cfg))
    .with_device(device_of(ctx.cfg)?);
    Ok(Box::new(NativeBackend { reg_name, inner: gemm }))
}

fn build_macro_hybrid(ctx: &BackendCtx) -> Result<Box<dyn Backend>> {
    build_native(ctx, "macro-hybrid", ctx.cfg.mode)
}

fn build_macro_dcim(ctx: &BackendCtx) -> Result<Box<dyn Backend>> {
    build_native(ctx, "macro-dcim", CimMode::Dcim)
}

fn build_macro_acim(ctx: &BackendCtx) -> Result<Box<dyn Backend>> {
    build_native(ctx, "macro-acim", CimMode::Acim)
}

/// The `macro-fleet` registry entry: [`FleetGemm`] over K simulated
/// macros (geometry and hop costs from `[fleet]`), with the per-request
/// `placement` knob re-planning placement on demand.
#[derive(Clone)]
struct FleetBackend {
    inner: FleetGemm,
}

impl Backend for FleetBackend {
    fn gemm(
        &mut self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
        layer_idx: u64,
    ) -> Result<GemmResult> {
        self.inner.gemm(a, m, k, w, n, layer_idx)
    }

    fn prepare(&mut self, w: &[i32], n: usize, k: usize, layer_idx: u64) -> Result<()> {
        self.inner.prepare(w, n, k, layer_idx)
    }

    fn name(&self) -> &str {
        fleet::BACKEND_NAME
    }

    fn capabilities(&self) -> Capabilities {
        let mode = self.inner.base().mode;
        let dims = self.inner.fleet();
        let cost_model = self.inner.base().cost_model();
        Capabilities {
            available: true,
            mode,
            macros: dims.macros,
            residency_bytes: dims.residency_tiles as u64
                * fleet::tile_bytes(&self.inner.base().spec),
            programmable_thresholds: mode == CimMode::Osa,
            hybrid_boundary: matches!(mode, CimMode::Hcim | CimMode::Osa),
            pooling: self.inner.placement_mode() == PlacementMode::Auto,
            cost_model,
            memory_levels: if cost_model == MODEL_HIERARCHY { NUM_LEVELS } else { 0 },
            device: device_caps(self.inner.base().device()),
            description: "K-macro fleet over the native simulator",
        }
    }

    fn apply(&mut self, knobs: &BackendKnobs) -> Result<()> {
        // placement first: a mode change rebuilds the fleet wrapper,
        // which re-pins the plan-cache scope and drops the cached
        // placements — the scalar knobs then land on the rebuilt base
        if let Some(p) = &knobs.placement {
            let mode = PlacementMode::parse(p).ok_or_else(|| {
                anyhow::anyhow!("unknown placement {p:?} (one of: auto, replicate, resident)")
            })?;
            if mode != self.inner.placement_mode() {
                self.inner = FleetGemm::new(
                    self.inner.base().clone(),
                    self.inner.fleet(),
                    mode,
                    self.inner.hop_energy_fj,
                    self.inner.hop_latency_cycles,
                );
            }
        }
        let base = self.inner.base_mut();
        if let Some(seed) = knobs.noise_seed {
            base.noise_seed = seed;
        }
        if let Some(b) = knobs.fixed_b {
            base.fixed_b = b;
        }
        if let Some(ts) = &knobs.thresholds {
            if ts.as_slice() != base.ose.thresholds() {
                base.ose = Ose::with_default_candidates(ts.clone())?;
            }
        }
        Ok(())
    }

    fn thresholds(&self) -> Option<Vec<i32>> {
        Some(self.inner.base().ose.thresholds().to_vec())
    }

    fn clone_backend(&self) -> Result<Box<dyn Backend>> {
        Ok(Box::new(self.clone()))
    }
}

fn build_macro_fleet(ctx: &BackendCtx) -> Result<Box<dyn Backend>> {
    let base = MacroGemm::new(
        ctx.cfg.mode,
        ctx.cfg.spec,
        ctx.cfg.fixed_b,
        ctx.cfg.thresholds.clone(),
        ctx.cfg.noise_seed,
    )?
    .with_plan_cache(ctx.plans.clone())
    .with_pool(ctx.pool.clone())
    .with_hierarchy(hierarchy_of(ctx.cfg))
    .with_device(device_of(ctx.cfg)?);
    let mode = PlacementMode::parse(&ctx.cfg.fleet_placement).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown [fleet] placement {:?} (one of: auto, replicate, resident)",
            ctx.cfg.fleet_placement
        )
    })?;
    let dims = FleetDims {
        macros: ctx.cfg.fleet_macros.max(1),
        residency_tiles: ctx.cfg.fleet_residency_tiles.max(1),
    };
    let inner = FleetGemm::new(
        base,
        dims,
        mode,
        ctx.cfg.fleet_hop_energy_fj,
        ctx.cfg.fleet_hop_latency_cycles,
    );
    Ok(Box::new(FleetBackend { inner }))
}

/// The PJRT artifact runtime as a registry entry.  Without the `pjrt`
/// feature the stub `Runtime::load` fails with its canonical
/// "unavailable" error, which this factory surfaces unchanged — the
/// entry is *registered* either way so selection errors are precise.
#[cfg(not(feature = "pjrt"))]
fn build_pjrt(ctx: &BackendCtx) -> Result<Box<dyn Backend>> {
    let _rt = crate::runtime::Runtime::load(&ctx.cfg.artifacts_dir, false)?;
    unreachable!("the stub Runtime::load always errors")
}

#[cfg(feature = "pjrt")]
fn build_pjrt(ctx: &BackendCtx) -> Result<Box<dyn Backend>> {
    // Each backend instance currently loads its own Runtime (one per
    // coordinator worker at startup).  If that load cost ever matters,
    // cache one Arc<Runtime> per Engine and hand clones to instances —
    // PjrtBackend already holds the runtime behind an Arc.
    let rt = crate::runtime::Runtime::load(&ctx.cfg.artifacts_dir, false)?;
    Ok(Box::new(PjrtBackend {
        rt: Arc::new(rt),
        mode: ctx.cfg.mode,
        thresholds: ctx.cfg.thresholds.clone(),
        fixed_b: ctx.cfg.fixed_b,
        noise_seed: ctx.cfg.noise_seed,
        plans: ctx.plans.clone(),
    }))
}

/// Owning wrapper over the borrowed `runtime::PjrtGemm<'r>`: holds the
/// runtime in an `Arc` and constructs the thin per-call engine on
/// demand (plans are shared, so the per-call construction cost is one
/// `Ose` build).
#[cfg(feature = "pjrt")]
struct PjrtBackend {
    rt: Arc<crate::runtime::Runtime>,
    mode: CimMode,
    thresholds: Vec<i32>,
    fixed_b: i32,
    noise_seed: u64,
    plans: Arc<PlanCache>,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    fn engine(&self) -> Result<crate::runtime::PjrtGemm<'_>> {
        let mut g =
            crate::runtime::PjrtGemm::new(&self.rt, self.mode, self.thresholds.clone())?
                .with_plan_cache(self.plans.clone());
        g.fixed_b = self.fixed_b;
        g.noise_seed = self.noise_seed;
        Ok(g)
    }
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn gemm(
        &mut self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
        layer_idx: u64,
    ) -> Result<GemmResult> {
        self.engine()?.gemm(a, m, k, w, n, layer_idx)
    }

    fn prepare(&mut self, w: &[i32], n: usize, k: usize, layer_idx: u64) -> Result<()> {
        self.engine()?.prepare(w, n, k, layer_idx)
    }

    fn name(&self) -> &str {
        "pjrt"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            available: true,
            mode: self.mode,
            macros: 1,
            residency_bytes: fleet::tile_bytes(&crate::spec::MacroSpec::default()),
            programmable_thresholds: self.mode == CimMode::Osa,
            hybrid_boundary: matches!(self.mode, CimMode::Hcim | CimMode::Osa),
            pooling: false,
            // the artifact runtime prices through the compact model only
            cost_model: crate::energy::hierarchy::MODEL_COMPACT,
            memory_levels: 0,
            // the artifact bakes the baseline thermal-noise model in
            device: DeviceCaps {
                model: "gaussian-thermal",
                sigma: crate::spec::SIGMA_CODE,
                s_ou: 0,
            },
            description: "AOT PJRT artifact runtime",
        }
    }

    fn apply(&mut self, knobs: &BackendKnobs) -> Result<()> {
        if let Some(seed) = knobs.noise_seed {
            self.noise_seed = seed;
        }
        if let Some(b) = knobs.fixed_b {
            self.fixed_b = b;
        }
        if let Some(ts) = &knobs.thresholds {
            self.thresholds = ts.clone();
        }
        Ok(())
    }

    fn thresholds(&self) -> Option<Vec<i32>> {
        Some(self.thresholds.clone())
    }

    fn clone_backend(&self) -> Result<Box<dyn Backend>> {
        Ok(Box::new(Self {
            rt: self.rt.clone(),
            mode: self.mode,
            thresholds: self.thresholds.clone(),
            fixed_b: self.fixed_b,
            noise_seed: self.noise_seed,
            plans: self.plans.clone(),
        }))
    }
}

// ------------------------------------------------------- request/response

/// Per-request options, shared verbatim by in-process callers and the
/// `POST /v2/infer` wire schema (DESIGN.md §12).
#[derive(Debug, Clone, PartialEq)]
pub struct InferOptions {
    /// QoS tier (gold / silver / batch).
    pub tier: Tier,
    /// Execution backend override; `None` = the engine's active backend.
    pub backend: Option<String>,
    /// Noise-seed override (reproducible analog noise per request).
    pub noise_seed: Option<u64>,
    /// Digital/analog boundary override in `0..=15` (HCIM-mode
    /// backends); finer (lower) = more digital = more precise.
    pub boundary: Option<i32>,
    /// Fleet placement override (`auto` / `replicate` / `resident`);
    /// meaningful on the `macro-fleet` backend, validated at submission.
    pub placement: Option<String>,
}

impl Default for InferOptions {
    fn default() -> Self {
        Self {
            tier: Tier::Silver,
            backend: None,
            noise_seed: None,
            boundary: None,
            placement: None,
        }
    }
}

/// One inference request: a 32x32x3 uint8 image plus options.
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub image: Vec<u8>,
    pub options: InferOptions,
}

impl InferRequest {
    pub fn new(image: Vec<u8>) -> Self {
        Self { image, options: InferOptions::default() }
    }

    pub fn with_tier(mut self, tier: Tier) -> Self {
        self.options.tier = tier;
        self
    }
}

/// One inference response (the coordinator's `Response` type).
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: u64,
    pub logits: Vec<f32>,
    pub pred: usize,
    pub tier: Tier,
    /// Registry name of the backend that served this request.
    pub backend: String,
    pub latency: Duration,
    /// Size of the engine batch this request rode in.
    pub batch_size: usize,
    /// Modeled energy of this request's equal share of its batch
    /// forward, joules (macro breakdown + movement + fleet transfer).
    /// `0.0` when the request was answered with an error before a
    /// forward completed.
    pub energy_j: f64,
    /// Set when the request was *answered*, not served (`logits` is
    /// empty or poisoned, `pred` is meaningless).
    pub error: Option<String>,
}

// ----------------------------------------------------------------- Engine

/// The assembled engine: graph + registry + shared plan cache + tile
/// pool + the active backend name.  Cheap to share behind an `Arc`;
/// every [`Engine::backend`] call hands out an independent instance
/// wired onto the shared caches.
pub struct Engine {
    cfg: SystemConfig,
    graph: Arc<QGraph>,
    registry: Arc<BackendRegistry>,
    plans: Arc<PlanCache>,
    pool: Arc<ExecPool>,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// The resolved configuration (includes the active backend name and
    /// thread count).
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    pub fn graph(&self) -> &Arc<QGraph> {
        &self.graph
    }

    /// The active backend's registry name.
    pub fn backend_name(&self) -> &str {
        &self.cfg.backend
    }

    pub fn registry(&self) -> &BackendRegistry {
        &self.registry
    }

    /// Worker-thread count of the shared tile pool.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn pool(&self) -> &Arc<ExecPool> {
        &self.pool
    }

    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plans
    }

    /// Plan-cache activity across every backend this engine handed out.
    pub fn plan_stats(&self) -> PlanCacheStats {
        self.plans.stats()
    }

    fn ctx<'a>(&self, cfg: &'a SystemConfig) -> BackendCtx<'a> {
        BackendCtx { cfg, plans: self.plans.clone(), pool: self.pool.clone() }
    }

    /// Build an instance of the active backend.
    pub fn backend(&self) -> Result<Box<dyn Backend>> {
        self.backend_named(&self.cfg.backend)
    }

    /// Build a backend by registry name (shares the plan cache + pool).
    pub fn backend_named(&self, name: &str) -> Result<Box<dyn Backend>> {
        self.registry.build(name, &self.ctx(&self.cfg))
    }

    /// Build a native backend pinned to an explicit CIM mode under an
    /// explicit config — the figure harnesses' entry point (ablation
    /// overrides mutate a copy of the config after load).
    pub fn backend_with(&self, cfg: &SystemConfig, mode: CimMode) -> Result<Box<dyn Backend>> {
        let name = match mode {
            CimMode::Dcim => "macro-dcim",
            CimMode::Acim => "macro-acim",
            _ => "macro-hybrid",
        };
        let mut c = cfg.clone();
        c.mode = mode;
        self.registry.build(name, &self.ctx(&c))
    }

    /// [`Engine::backend_with`] under the engine's own config.
    pub fn backend_for_mode(&self, mode: CimMode) -> Result<Box<dyn Backend>> {
        self.backend_with(&self.cfg, mode)
    }

    /// The active backend over a *fresh, unshared* plan cache — for
    /// cold-start measurement (the pipeline bench) and isolation tests.
    pub fn backend_cold(&self) -> Result<Box<dyn Backend>> {
        let ctx = BackendCtx {
            cfg: &self.cfg,
            plans: Arc::new(PlanCache::new()),
            pool: self.pool.clone(),
        };
        self.registry.build(&self.cfg.backend, &ctx)
    }

    /// A model executor over a fresh instance of the active backend.
    pub fn executor(&self) -> Result<Executor<'_, Box<dyn Backend>>> {
        Ok(Executor::new(self.graph.as_ref(), self.backend()?))
    }
}

// ---------------------------------------------------------------- builder

/// Step-wise [`Engine`] construction:
///
/// ```no_run
/// # use osa_hcim::engine::Engine;
/// # use osa_hcim::nn::QGraph;
/// # use std::sync::Arc;
/// let engine = Engine::builder()
///     .graph(Arc::new(QGraph::synthetic()))
///     .backend("macro-dcim")
///     .threads(2)
///     .loss_profile("loose")
///     .build()?;
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Default)]
pub struct EngineBuilder {
    cfg: Option<SystemConfig>,
    graph: Option<Arc<QGraph>>,
    backend: Option<String>,
    threads: Option<usize>,
    fleet: Option<usize>,
    loss_profile: Option<String>,
    registry: Option<Arc<BackendRegistry>>,
    pool: Option<Arc<ExecPool>>,
    plans: Option<Arc<PlanCache>>,
}

impl EngineBuilder {
    /// Start from a full [`SystemConfig`] (defaults otherwise).
    pub fn config(mut self, cfg: SystemConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// The model graph (required).
    pub fn graph(mut self, graph: Arc<QGraph>) -> Self {
        self.graph = Some(graph);
        self
    }

    /// Active backend by registry name (overrides `[engine] backend`).
    pub fn backend(mut self, name: impl Into<String>) -> Self {
        self.backend = Some(name.into());
        self
    }

    /// Exact tile-pool size (overrides `[engine] threads`; not clamped
    /// to the core count — parity tests size pools explicitly).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Fleet size K for the `macro-fleet` backend (overrides
    /// `[fleet] macros`).  Pair with `.backend("macro-fleet")` to make
    /// the fleet the active backend.
    pub fn fleet(mut self, macros: usize) -> Self {
        self.fleet = Some(macros);
        self
    }

    /// Scale the calibrated OSE thresholds onto a loss-constraint
    /// profile (`tight` / `normal` / `loose` / `max-eff`) — the static
    /// flavor of what the serving governor does per tier.
    pub fn loss_profile(mut self, profile: impl Into<String>) -> Self {
        self.loss_profile = Some(profile.into());
        self
    }

    /// A custom backend registry (defaults to
    /// [`BackendRegistry::builtin`]).
    pub fn registry(mut self, registry: Arc<BackendRegistry>) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Share an existing tile pool instead of creating one.
    pub fn pool(mut self, pool: Arc<ExecPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Share an existing plan cache instead of creating one.
    pub fn plan_cache(mut self, plans: Arc<PlanCache>) -> Self {
        self.plans = Some(plans);
        self
    }

    /// Validate and assemble.  Fails fast (typed, field-named errors)
    /// on: missing graph, invalid config, zero threads, unknown or
    /// unavailable active backend — the error lists every registered
    /// backend.
    pub fn build(self) -> Result<Engine> {
        let mut cfg = self.cfg.unwrap_or_default();
        if let Some(t) = self.threads {
            if t == 0 {
                anyhow::bail!("EngineBuilder::threads must be >= 1");
            }
            cfg.engine_threads = t;
        }
        if let Some(kf) = self.fleet {
            if kf == 0 {
                anyhow::bail!("EngineBuilder::fleet must be >= 1");
            }
            cfg.fleet_macros = kf;
        }
        if let Some(b) = self.backend {
            cfg.backend = b;
        }
        if let Some(profile) = &self.loss_profile {
            cfg.thresholds = crate::osa::profile_thresholds(&cfg.thresholds, profile)
                .with_context(|| {
                    format!(
                        "unknown loss profile {profile:?} (one of: {})",
                        crate::osa::PROFILES.join(", ")
                    )
                })?;
        }
        cfg.validate()?;
        let graph = self
            .graph
            .context("EngineBuilder: a graph is required (call .graph(Arc<QGraph>))")?;
        let registry =
            self.registry.unwrap_or_else(|| Arc::new(BackendRegistry::builtin()));
        let pool = self.pool.unwrap_or_else(|| {
            if self.threads.is_some() {
                ExecPool::new(cfg.engine_threads)
            } else {
                // auto-sized pools are clamped to the machine: engine
                // callers (coordinator workers) block on the pool for
                // the duration of their GEMMs, so oversubscription buys
                // nothing (DESIGN.md §11)
                let cores =
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
                ExecPool::new(cfg.resolved_engine_threads().min(cores).max(1))
            }
        });
        let plans = self.plans.unwrap_or_else(|| Arc::new(PlanCache::new()));
        let engine = Engine { cfg, graph, registry, plans, pool };
        // fail fast: an unknown or unavailable active backend is a
        // build-time error, not a first-request surprise
        engine.backend().with_context(|| {
            format!("building active backend {:?}", engine.cfg.backend)
        })?;
        Ok(engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_engine() -> Engine {
        Engine::builder().graph(Arc::new(QGraph::synthetic())).build().unwrap()
    }

    #[test]
    fn builtin_registry_names_and_order() {
        let r = BackendRegistry::builtin();
        assert_eq!(
            r.names(),
            vec!["macro-hybrid", "macro-dcim", "macro-acim", "macro-fleet", "pjrt"]
        );
        assert!(r.get("macro-hybrid").unwrap().available);
        assert!(r.get("macro-fleet").unwrap().available);
        #[cfg(not(feature = "pjrt"))]
        assert!(!r.get("pjrt").unwrap().available);
    }

    #[test]
    fn fleet_backend_reports_structured_capabilities() {
        let engine = Engine::builder()
            .graph(Arc::new(QGraph::synthetic()))
            .backend("macro-fleet")
            .fleet(4)
            .build()
            .unwrap();
        let mut b = engine.backend().unwrap();
        assert_eq!(b.name(), "macro-fleet");
        let caps = b.capabilities();
        assert_eq!(caps.macros, 4);
        assert!(caps.pooling, "auto placement pools by default");
        // residency = residency_tiles x tile bytes on the paper geometry
        let tile = fleet::tile_bytes(&engine.config().spec);
        assert_eq!(
            caps.residency_bytes,
            engine.config().fleet_residency_tiles as u64 * tile
        );
        // the placement knob re-plans: resident mode never pools
        b.apply(&BackendKnobs { placement: Some("resident".into()), ..Default::default() })
            .unwrap();
        assert!(!b.capabilities().pooling);
        assert_eq!(b.capabilities().macros, 4);
        let err = b
            .apply(&BackendKnobs { placement: Some("bogus".into()), ..Default::default() })
            .unwrap_err();
        assert!(err.to_string().contains("placement"), "{err}");
        // single-macro backends ignore the knob instead of failing
        let mut h = engine.backend_named("macro-hybrid").unwrap();
        h.apply(&BackendKnobs { placement: Some("resident".into()), ..Default::default() })
            .unwrap();
        assert_eq!(h.capabilities().macros, 1);
        assert!(!h.capabilities().pooling);
    }

    #[test]
    fn builder_rejects_zero_fleet() {
        let err = Engine::builder()
            .graph(Arc::new(QGraph::synthetic()))
            .fleet(0)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{err}");
    }

    #[test]
    fn capabilities_expose_device_block() {
        let engine = synth_engine();
        let caps = engine.backend().unwrap().capabilities();
        assert_eq!(caps.device.model, "gaussian-thermal");
        assert_eq!(caps.device.sigma, crate::spec::SIGMA_CODE);
        assert_eq!(caps.device.s_ou, 0);
        let fleet = engine.backend_named("macro-fleet").unwrap().capabilities();
        assert_eq!(fleet.device, caps.device);
    }

    #[test]
    fn unknown_backend_error_lists_registered() {
        let e = synth_engine().backend_named("macro-gpu").unwrap_err();
        let be = e.downcast_ref::<BackendError>().expect("typed BackendError");
        match be {
            BackendError::Unknown { requested, registered } => {
                assert_eq!(requested, "macro-gpu");
                assert!(registered.contains(&"macro-hybrid".to_string()));
            }
            other => panic!("wrong variant: {other:?}"),
        }
        assert!(e.to_string().contains("macro-dcim"), "{e}");
    }

    #[test]
    fn builder_requires_graph_and_valid_threads() {
        assert!(Engine::builder().build().is_err());
        let err = Engine::builder()
            .graph(Arc::new(QGraph::synthetic()))
            .threads(0)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{err}");
    }

    #[test]
    fn builder_rejects_unknown_backend_and_profile() {
        let err = Engine::builder()
            .graph(Arc::new(QGraph::synthetic()))
            .backend("macro-tpu")
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("registered"), "{err:#}");
        let err = Engine::builder()
            .graph(Arc::new(QGraph::synthetic()))
            .loss_profile("bogus")
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("loss profile"), "{err:#}");
    }

    #[test]
    fn loss_profile_scales_thresholds_monotonically() {
        let base = SystemConfig::default().thresholds;
        let loose = Engine::builder()
            .graph(Arc::new(QGraph::synthetic()))
            .loss_profile("loose")
            .build()
            .unwrap();
        let got = loose.config().thresholds.clone();
        assert!(got.iter().zip(&base).all(|(a, b)| a >= b), "{got:?} vs {base:?}");
        assert!(got.iter().sum::<i32>() > base.iter().sum::<i32>());
        // normal is the calibrated identity
        let normal = Engine::builder()
            .graph(Arc::new(QGraph::synthetic()))
            .loss_profile("normal")
            .build()
            .unwrap();
        assert_eq!(normal.config().thresholds, base);
    }

    #[test]
    fn backends_share_the_engine_plan_cache() {
        let engine = synth_engine();
        let mut a = engine.backend().unwrap();
        let mut b = engine.backend().unwrap();
        let w: Vec<i32> = (0..4 * 16).map(|i| (i % 7) as i32 - 3).collect();
        a.prepare(&w, 4, 16, 0).unwrap();
        b.prepare(&w, 4, 16, 0).unwrap();
        let stats = engine.plan_stats();
        assert_eq!((stats.misses, stats.hits), (1, 1), "second prepare must hit");
        // a cold backend does NOT share it
        let mut c = engine.backend_cold().unwrap();
        c.prepare(&w, 4, 16, 0).unwrap();
        assert_eq!(engine.plan_stats().misses, 1);
    }

    #[test]
    fn knobs_round_trip() {
        let engine = synth_engine();
        let mut b = engine.backend().unwrap();
        assert_eq!(b.name(), "macro-hybrid");
        let caps = b.capabilities();
        assert!(caps.available && caps.programmable_thresholds, "{caps:?}");
        let ts = vec![1, 2, 3, 4, 5];
        b.apply(&BackendKnobs {
            noise_seed: Some(7),
            fixed_b: Some(6),
            thresholds: Some(ts.clone()),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(b.thresholds(), Some(ts));
        // descending thresholds are an Ose validation error
        assert!(b
            .apply(&BackendKnobs { thresholds: Some(vec![5, 1, 0, 0, 0]), ..Default::default() })
            .is_err());
    }

    #[test]
    fn mode_pinned_backends_report_their_mode() {
        let engine = synth_engine();
        let d = engine.backend_for_mode(CimMode::Dcim).unwrap();
        assert_eq!(d.name(), "macro-dcim");
        assert_eq!(d.capabilities().mode, CimMode::Dcim);
        assert!(!d.capabilities().programmable_thresholds);
        let a = engine.backend_for_mode(CimMode::Acim).unwrap();
        assert_eq!(a.name(), "macro-acim");
        let h = engine.backend_for_mode(CimMode::Hcim).unwrap();
        assert_eq!(h.name(), "macro-hybrid");
        assert_eq!(h.capabilities().mode, CimMode::Hcim);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_is_registered_but_unavailable() {
        let engine = synth_engine();
        assert!(!engine.registry().get("pjrt").unwrap().available);
        let err = engine.backend_named("pjrt").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn executor_runs_on_a_boxed_backend() {
        let engine = synth_engine();
        let mut exec = engine.executor().unwrap();
        exec.preplan().unwrap();
        let img = vec![100u8; 32 * 32 * 3];
        let (logits, stats) = exec.forward(&img, 1).unwrap();
        assert_eq!(logits.len(), engine.graph().num_classes);
        assert!(stats.account.macro_ops > 0);
    }

    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Engine>();
        assert_send_sync::<BackendRegistry>();
    }
}
