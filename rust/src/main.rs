//! `osa-hcim` — CLI entrypoint of the L3 coordinator.
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md §9):
//! `fig 5a|5b|6|7|8a|8b|9`, `table1`, plus `run` (single-shot batch
//! inference), `serve` (HTTP gateway with `--listen`, or the in-process
//! coordinator demo), `calibrate` (Fig 4b threshold search) and
//! `validate` (artifact/spec/PJRT sanity).

use anyhow::{bail, Context, Result};
use osa_hcim::cli::{Cli, Command, Opt};
use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::engine::Engine;
use osa_hcim::figures::{self, FigCtx};
use osa_hcim::nn::{accuracy, Executor, QGraph};
use std::path::PathBuf;

fn common_opts() -> Vec<Opt> {
    vec![
        Opt::value("artifacts", "artifacts directory", Some("artifacts")),
        Opt::value("config", "TOML config file", None),
        Opt::value("results", "directory for result text files", Some("results")),
        Opt::value("mode", "cim mode: dcim|hcim|osa|acim", Some("osa")),
        Opt::value(
            "backend",
            "execution backend: macro-hybrid|macro-dcim|macro-acim|macro-fleet|pjrt",
            None,
        ),
        Opt::value("fleet", "macro-fleet size K (>= 1; use with --backend macro-fleet)", None),
        Opt::value("placement", "fleet placement policy: auto|replicate|resident", None),
        Opt::value("fixed-b", "boundary for hcim mode", Some("8")),
        Opt::value("images", "number of test images", Some("128")),
        Opt::value("calib-images", "images for threshold calibration", Some("48")),
        Opt::value("sigma", "ADC noise sigma in code units", None),
        Opt::value(
            "device",
            "analog device model: gaussian-thermal|ideal|capacitor-mismatch|lognormal-conductance",
            None,
        ),
        Opt::value("device-sigma", "device variation sigma (defaults to --sigma)", None),
        Opt::value("fs-frac", "ADC full-scale fraction (ablation override)", None),
        Opt::value("nq-shift", "OSE N/Q shift (ablation override)", None),
        Opt::value("seed", "noise seed", None),
        Opt::value("thresholds", "comma-separated OSE thresholds", None),
        Opt::value("threads", "tile-execution pool size, >= 1 (omit for all cores)", None),
    ]
}

fn build_config(args: &osa_hcim::cli::Args) -> Result<SystemConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => SystemConfig::from_toml_file(&PathBuf::from(path))?,
        None => SystemConfig::default(),
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(dir);
    }
    if let Some(mode) = args.get("mode") {
        cfg.mode = CimMode::parse(mode)?;
    }
    if let Some(backend) = args.get("backend") {
        cfg.backend = backend.to_string();
    }
    if args.get("fleet").is_some() {
        let k = args.get_usize("fleet", 0)?;
        if k == 0 {
            bail!("--fleet must be >= 1");
        }
        cfg.fleet_macros = k;
    }
    if let Some(p) = args.get("placement") {
        cfg.fleet_placement = p.to_string();
    }
    cfg.fixed_b = args.get_i32("fixed-b", cfg.fixed_b)?;
    if let Some(sigma) = args.get("sigma") {
        cfg.spec.sigma_code = sigma.parse()?;
    }
    if let Some(model) = args.get("device") {
        cfg.device_model = model.to_string();
    }
    if let Some(sigma) = args.get("device-sigma") {
        cfg.device_sigma = Some(sigma.parse()?);
    }
    cfg.noise_seed = args.get_u64("seed", cfg.noise_seed)?;
    if args.get("threads").is_some() {
        let threads = args.get_usize("threads", 0)?;
        if threads == 0 {
            bail!("--threads must be >= 1 (omit the flag for auto-sizing)");
        }
        cfg.engine_threads = threads;
    }
    if let Some(ts) = args.get("thresholds") {
        cfg.thresholds = ts
            .split(',')
            .map(|s| s.trim().parse::<i32>().context("bad threshold"))
            .collect::<Result<_>>()?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn main() -> Result<()> {
    osa_hcim::util::logging::init();
    let cli = Cli {
        bin: "osa-hcim",
        about: "OSA-HCIM: on-the-fly saliency-aware hybrid SRAM CIM — full-system reproduction",
        commands: vec![
            Command {
                name: "run",
                about: "batch inference on the test set, reporting accuracy + TOPS/W",
                opts: common_opts(),
            },
            Command {
                name: "serve",
                about: "serve inference: HTTP gateway (--listen) or in-process demo",
                opts: {
                    let mut o = common_opts();
                    o.push(Opt::value("requests", "requests to submit (demo mode)", Some("256")));
                    o.push(Opt::value("workers", "worker threads", Some("4")));
                    o.push(Opt::value("max-batch", "max requests per batch", Some("32")));
                    o.push(Opt::value(
                        "listen",
                        "bind the HTTP gateway here (e.g. 127.0.0.1:8080) instead of the demo",
                        None,
                    ));
                    o.push(Opt::value("queue-cap", "bound of each QoS tier's queue", None));
                    o.push(Opt::value(
                        "max-conns",
                        "max concurrent HTTP connections (event loop) / worker pool size",
                        None,
                    ));
                    o.push(Opt::value(
                        "read-timeout-ms",
                        "keep-alive per-read timeout; slowloris deadline is 4x (0 disables)",
                        None,
                    ));
                    o.push(Opt::flag(
                        "no-keep-alive",
                        "one request per connection (Connection: close on every response)",
                    ));
                    o.push(Opt::flag(
                        "event-loop",
                        "force the readiness-driven gateway (default on unix)",
                    ));
                    o.push(Opt::flag(
                        "no-event-loop",
                        "use the thread-per-connection gateway instead of the event loop",
                    ));
                    o.push(Opt::flag("no-governor", "disable the dynamic precision governor"));
                    o.push(Opt::value(
                        "energy-budget-w",
                        "modeled macro power budget in watts (governor)",
                        None,
                    ));
                    o.push(Opt::value(
                        "slow-ms",
                        "log requests slower than this many milliseconds",
                        None,
                    ));
                    o.push(Opt::value(
                        "trace-capacity",
                        "span ring capacity for /debug/trace (power of two)",
                        None,
                    ));
                    o.push(Opt::flag("no-trace", "disable per-request span tracing"));
                    o
                },
            },
            Command {
                name: "calibrate",
                about: "Fig 4b threshold search for a loss-constraint profile",
                opts: {
                    let mut o = common_opts();
                    o.push(Opt::value("profile", "tight|normal|loose|max-eff", Some("normal")));
                    o
                },
            },
            Command {
                name: "fig",
                about: "regenerate a paper figure: 5a 5b 6 7 8a 8b 9",
                opts: {
                    let mut o = common_opts();
                    o.push(Opt::value("image", "test-image index for fig 8a", Some("0")));
                    o.push(Opt::value("layers", "comma list of layers for fig 8a", None));
                    o
                },
            },
            Command {
                name: "table1",
                about: "regenerate Table I (\"This Work\" column)",
                opts: common_opts(),
            },
            Command {
                name: "sweep",
                about: "Monte Carlo design-space sweep: boundary x device sigma x seeds",
                opts: {
                    let mut o = common_opts();
                    o.push(Opt::value(
                        "boundaries",
                        "comma-separated hybrid boundaries to sweep",
                        Some("10,8,6"),
                    ));
                    o.push(Opt::value(
                        "sigmas",
                        "comma-separated device sigmas to sweep",
                        Some("0.0,0.3,0.6"),
                    ));
                    o.push(Opt::value("mc-seeds", "Monte Carlo seeds per grid cell", Some("3")));
                    o.push(Opt::value(
                        "corner-sigma",
                        "device corner for the governor-ladder eval",
                        None,
                    ));
                    o
                },
            },
            Command {
                name: "validate",
                about: "check artifacts, spec parity and the PJRT runtime",
                opts: common_opts(),
            },
        ],
    };

    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((sub, args)) = cli.parse(&argv)? else {
        return Ok(());
    };
    let cfg = build_config(&args)?;
    let results_dir = PathBuf::from(args.get_or("results", "results"));

    match sub.as_str() {
        "run" => {
            let mut ctx = FigCtx::load(cfg)?;
            // ablation overrides depart from spec.json intentionally
            if let Some(ff) = args.get("fs-frac") {
                ctx.cfg.spec.adc_fs_frac = ff.parse()?;
            }
            if let Some(nq) = args.get("nq-shift") {
                ctx.cfg.spec.nq_shift = nq.parse()?;
            }
            let n = args.get_usize("images", 128)?;
            let ev = ctx.eval_mode(ctx.cfg.mode, ctx.cfg.fixed_b, &ctx.cfg.thresholds, n)?;
            println!(
                "mode={} images={n} acc={:.2}% ce={:.4} tops_per_watt={:.2} \
                 energy_per_image={:.1}nJ macro_ops={}",
                ctx.cfg.mode.name(),
                ev.acc * 100.0,
                ev.ce,
                ev.tops_w,
                ev.energy_nj_per_img,
                ev.macro_ops
            );
        }
        "serve" => {
            let mut cfg = cfg;
            cfg.workers = args.get_usize("workers", cfg.workers)?;
            cfg.max_batch = args.get_usize("max-batch", cfg.max_batch)?;
            cfg.queue_cap = args.get_usize("queue-cap", cfg.queue_cap)?;
            cfg.max_conns = args.get_usize("max-conns", cfg.max_conns)?;
            cfg.read_timeout_ms = args.get_u64("read-timeout-ms", cfg.read_timeout_ms)?;
            if args.flag("no-keep-alive") {
                cfg.keep_alive = false;
            }
            if args.flag("event-loop") {
                cfg.event_loop = true;
            }
            if args.flag("no-event-loop") {
                cfg.event_loop = false;
            }
            if args.flag("no-governor") {
                cfg.governor = false;
            }
            cfg.energy_budget_w = args.get_f64("energy-budget-w", cfg.energy_budget_w)?;
            cfg.obs_slow_ms = args.get_u64("slow-ms", cfg.obs_slow_ms)?;
            cfg.obs_trace_capacity = args.get_usize("trace-capacity", cfg.obs_trace_capacity)?;
            if args.flag("no-trace") {
                cfg.obs_trace = false;
            }
            if let Some(listen) = args.get("listen") {
                // gateway mode: serve HTTP until the process is killed.
                // Fall back to the synthetic graph when the AOT artifacts
                // are not built so the network surface is always testable.
                let graph = match FigCtx::load(cfg.clone()) {
                    Ok(ctx) => ctx.engine.graph().clone(),
                    Err(e) => {
                        eprintln!("artifacts not available ({e:#}); serving the synthetic graph");
                        std::sync::Arc::new(QGraph::synthetic())
                    }
                };
                let engine = Engine::builder().config(cfg.clone()).graph(graph).build()?;
                println!(
                    "engine: backend={} threads={} (registered: {})",
                    engine.backend_name(),
                    engine.threads(),
                    engine.registry().names().join(", ")
                );
                let gateway =
                    osa_hcim::serve::Gateway::with_engine(std::sync::Arc::new(engine), listen)?;
                let addr = gateway.addr();
                println!("gateway listening on http://{addr}");
                println!("  GET  http://{addr}/healthz");
                println!("  GET  http://{addr}/v1/version");
                println!("  GET  http://{addr}/v2/topology  (fleet placement + transfer cost)");
                println!("  GET  http://{addr}/metrics      (?format=prometheus for text)");
                println!("  GET  http://{addr}/debug/trace  (?n=K — Chrome trace-event spans)");
                println!(
                    "  curl -s -X POST http://{addr}/v2/infer -d \
                     '{{\"image\":[...3072 uint8...],\"options\":{{\"tier\":\"gold\",\
                     \"backend\":\"macro-hybrid\"}}}}'"
                );
                println!(
                    "  POST http://{addr}/v1/infer        (legacy adapter: \
                     '{{\"tier\":\"gold\",\"image\":[...]}}')"
                );
                println!(
                    "  POST http://{addr}/v1/infer_batch  (NDJSON: one image per line, \
                     per-line tier override)"
                );
                gateway.wait();
                return Ok(());
            }
            let ctx = FigCtx::load(cfg.clone())?;
            let graph = ctx.engine.graph().clone();
            let n = args.get_usize("requests", 256)?.min(ctx.ds.test_n());
            // the closed-loop demo submits everything up front: size the
            // admission bound so it exercises batching, not backpressure
            cfg.queue_cap = cfg.queue_cap.max(n);
            let engine = Engine::builder().config(cfg.clone()).graph(graph).build()?;
            let server =
                osa_hcim::coordinator::Server::with_engine(std::sync::Arc::new(engine))?;
            // demo drives all three QoS tiers round-robin
            let tiers = osa_hcim::serve::Tier::ALL;
            let mut rxs = Vec::new();
            for i in 0..n {
                let (img, _) = ctx.ds.test_batch(i, 1);
                rxs.push((i, server.submit_tier(img.to_vec(), tiers[i % tiers.len()])?));
            }
            let mut correct = 0usize;
            for (i, rx) in rxs {
                let resp = rx.recv().context("worker dropped the batch")?;
                if let Some(err) = &resp.error {
                    bail!("request {i} failed in the worker: {err}");
                }
                if resp.pred as i32 == ctx.ds.test_y[i] {
                    correct += 1;
                }
            }
            let plan_stats = server.plan_stats();
            let metrics = server.shutdown();
            println!(
                "serve: acc={:.2}%  {}  plan_cache: {} layers packed once, hit rate {:.1}%",
                correct as f64 / n as f64 * 100.0,
                metrics.report(&cfg.spec),
                plan_stats.layers,
                plan_stats.hit_rate() * 100.0
            );
            for tier in tiers {
                let t = metrics.tier(tier);
                println!(
                    "  tier {:<6} requests={} p50={:.1}ms p99={:.1}ms mean_B={:.2}",
                    tier.name(),
                    t.requests,
                    t.p50_latency_us() / 1e3,
                    t.p99_latency_us() / 1e3,
                    t.mean_boundary()
                );
            }
        }
        "calibrate" => {
            let ctx = FigCtx::load(cfg)?;
            let profile = args.get_or("profile", "normal").to_string();
            let constraints = osa_hcim::osa::loss_profile(&profile)
                .with_context(|| format!("unknown profile {profile}"))?;
            let calib_n = args.get_usize("calib-images", 48)?;
            let cal = figures::calibrate_osa(&ctx, &constraints, calib_n)?;
            println!(
                "profile={profile} thresholds={:?} final_loss={:.4} evals={}",
                cal.thresholds, cal.final_loss, cal.evals
            );
            for step in &cal.log {
                log::debug!("  level {} T={} loss={:.4}", step.level, step.threshold, step.loss);
            }
        }
        "fig" => {
            let which = args
                .positional
                .first()
                .context("which figure? fig 5a|5b|6|7|8a|8b|9")?
                .clone();
            let images = args.get_usize("images", 128)?;
            let calib = args.get_usize("calib-images", 48)?;
            let text = match which.as_str() {
                "5a" => figures::fig5a(),
                "5b" => figures::fig5b(4096, 7)?,
                "6" => figures::fig6(),
                "7" => figures::fig7(&FigCtx::load(cfg)?, images.min(16))?,
                "8a" => {
                    let ctx = FigCtx::load(cfg)?;
                    let idx = args.get_usize("image", 0)?;
                    let layers: Vec<&str> = args
                        .get("layers")
                        .map(|s| s.split(',').collect())
                        .unwrap_or_default();
                    figures::fig8a(&ctx, idx, &layers)?
                }
                "8b" => figures::fig8b(&FigCtx::load(cfg)?, images.min(32))?,
                "9" => figures::fig9(&FigCtx::load(cfg)?, images, calib)?.0,
                other => bail!("unknown figure {other}"),
            };
            figures::emit(&format!("fig{which}"), &text, &results_dir)?;
        }
        "table1" => {
            let ctx = FigCtx::load(cfg)?;
            let images = args.get_usize("images", 128)?;
            let calib = args.get_usize("calib-images", 48)?;
            let text = figures::table1(&ctx, images, calib)?;
            figures::emit("table1", &text, &results_dir)?;
        }
        "sweep" => {
            use osa_hcim::device::sweep;
            let parse_csv = |text: &str, what: &str| -> Result<Vec<f64>> {
                text.split(',')
                    .map(|p| p.trim().parse::<f64>().with_context(|| format!("bad {what} {p:?}")))
                    .collect()
            };
            let images = args.get_usize("images", 128)?;
            let grid = sweep::SweepGrid {
                boundaries: parse_csv(args.get_or("boundaries", "10,8,6"), "boundary")?
                    .iter()
                    .map(|&b| b as i32)
                    .collect(),
                sigmas: parse_csv(args.get_or("sigmas", "0.0,0.3,0.6"), "sigma")?,
                mc_seeds: args.get_usize("mc-seeds", 3)?,
                images,
                corner_sigma: args.get_f64("corner-sigma", cfg.device_corner_sigma)?,
            };
            // eval against the real test set when artifacts are built,
            // else against the DCIM-labeled synthetic set — the sweep
            // surface is meaningful (and reproducible) either way
            let (graph, eval) = match FigCtx::load(cfg.clone()) {
                Ok(ctx) => {
                    let graph = ctx.engine.graph().clone();
                    let n = images.min(ctx.ds.test_n());
                    let (imgs, labels) = ctx.ds.test_batch(0, n);
                    (graph, sweep::EvalSet::from_parts(imgs.to_vec(), labels.to_vec())?)
                }
                Err(e) => {
                    eprintln!("artifacts not available ({e:#}); sweeping the synthetic graph");
                    let graph = std::sync::Arc::new(QGraph::synthetic());
                    let eval = sweep::EvalSet::synthetic(&cfg, &graph, images)?;
                    (graph, eval)
                }
            };
            let mut grid = grid;
            grid.images = eval.labels.len();
            let progress = osa_hcim::obs::SweepProgress::new();
            let report = sweep::run(&cfg, &graph, &eval, &grid, &progress)?;
            std::fs::create_dir_all(&results_dir)?;
            let json_path = results_dir.join("SWEEP_device.json");
            let csv_path = results_dir.join("SWEEP_device.csv");
            std::fs::write(&json_path, report.to_json().to_string_compact())?;
            std::fs::write(&csv_path, report.to_csv())?;
            println!(
                "sweep: {} surface cells x {} seeds + {} ladder points over {} images",
                grid.boundaries.len() * grid.sigmas.len(),
                grid.mc_seeds,
                report.ladder.len(),
                grid.images
            );
            for c in &report.surface {
                println!(
                    "  b={:<3} sigma={:<5} acc={:.2}% [{:.2}%, {:.2}%] energy={:.1}nJ/img",
                    c.boundary,
                    c.sigma,
                    c.acc_mean * 100.0,
                    c.acc_min * 100.0,
                    c.acc_max * 100.0,
                    c.energy_nj
                );
            }
            for p in &report.ladder {
                println!(
                    "  ladder tier={:<6} level={} acc={:.2}%  (corner sigma {})",
                    p.tier,
                    p.level,
                    p.accuracy * 100.0,
                    grid.corner_sigma
                );
            }
            println!("wrote {} and {}", json_path.display(), csv_path.display());
            println!(
                "feed it back into serving: [device] sweep_report = {json_path:?} \
                 + sla_gold/sla_silver/sla_batch floors"
            );
        }
        "validate" => {
            cfg.spec.validate_against_artifacts(&cfg.artifacts_dir)?;
            println!("spec.json: OK");
            let ds = osa_hcim::nn::data::Dataset::load(&cfg.artifacts_dir)?;
            println!("dataset.rten: OK ({} train / {} test)", ds.train_n(), ds.test_n());
            let graph = QGraph::load(&cfg.artifacts_dir)?;
            println!("graph.json + weights.rten: OK ({} convs)", graph.convs.len());
            let golden = osa_hcim::nn::data::Golden::load(&cfg.artifacts_dir)?;
            println!("golden.rten: OK (float acc {:.2}%)", golden.float_acc * 100.0);
            // native DCIM must reproduce the python DCIM golden logits —
            // driven through the unified engine API like everything else
            let engine = Engine::builder()
                .config(cfg.clone())
                .graph(std::sync::Arc::new(graph.clone()))
                .build()?;
            println!(
                "engine: backend={} threads={} (registered: {})",
                engine.backend_name(),
                engine.threads(),
                engine.registry().names().join(", ")
            );
            let mut exec =
                Executor::new(&graph, engine.backend_for_mode(CimMode::Dcim)?);
            exec.preplan()?; // plan/execute split: pack every layer up front
            let n = golden.golden_n.min(16);
            let (imgs, _) = ds.test_batch(0, n);
            let (logits, _) = exec.forward(imgs, n)?;
            let mut max_err = 0.0f32;
            for (a, b) in logits.iter().zip(&golden.dcim_logits[..n * golden.classes]) {
                max_err = max_err.max((a - b).abs() / b.abs().max(1.0));
            }
            println!(
                "native DCIM vs python golden: max rel err {:.2e} over {n} images {}",
                max_err,
                if max_err < 1.5e-2 { "(OK)" } else { "(MISMATCH!)" }
            );
            if max_err >= 1.5e-2 {
                bail!("native DCIM diverges from the python golden");
            }
            match osa_hcim::runtime::Runtime::load(&cfg.artifacts_dir, true) {
                Ok(rt) => {
                    println!("PJRT runtime: OK ({})", rt.platform());
                    let float_logits = rt.model_forward_all(imgs, n, golden.classes)?;
                    let acc = accuracy(&float_logits, &ds.test_y[..n], golden.classes);
                    println!(
                        "PJRT float model on {n} images: acc {:.1}% (golden path)",
                        acc * 100.0
                    );
                }
                Err(e) => println!("PJRT runtime: skipped ({e})"),
            }
        }
        other => bail!("unhandled subcommand {other}"),
    }
    Ok(())
}
