//! Dataflow access-count model (DESIGN.md §15): walk one GEMM's
//! `(row-chunk, N-tile)` plan tiles — and, on a fleet, its placement —
//! and derive how many words move through every [`hierarchy`] level,
//! priced into per-level femtojoule terms.
//!
//! The walk is a *pure function* of `(m, plan geometry, placement,
//! hierarchy)`: no accumulation over execution order, so the resulting
//! f64s are bit-identical for any thread count and any fleet merge
//! order — the invariance the parity tests pin.
//!
//! Access-count derivation (one `gemm` call, `m` rows, weight
//! stationary; a word is one 8-bit operand):
//!
//! * **Weight fill**: every logical tile streams DRAM → weight SRAM
//!   once per call (`tiles x tile_words` reads and writes), then the
//!   SRAM fills each replica's cell groups (`x replicas`).  Charging
//!   the fill per call is conservative — a resident fleet amortizes it
//!   across calls — and keeps the model call-local and deterministic.
//! * **Weight-stationary reuse**: every row re-reads every resident
//!   tile from the cell groups (`m x tiles x tile_words`).  These reads
//!   are *counted* but priced at the `cell_group` read energy, which
//!   defaults to 0 because the cell read is already inside
//!   `e_dat_bitmac_fj` (no double pricing).
//! * **Input broadcast**: activations stream DRAM → activation SRAM
//!   (`m x k` in, staged padded as `m x k_pad`), then each row's
//!   K-slice is read once and broadcast to all N-tiles (`m x k_pad`
//!   reads).
//! * **Partial-sum writeback**: each output lane accumulates across
//!   `kt` K-tiles in the accumulation RF (`m x n_pad x kt` read-modify
//!   -writes), then results retire through the activation SRAM
//!   (`m x n_pad` writes) and out to DRAM (`m x n` unpadded).
//! * **Inter-macro hops**: split-K columns move `(k_span - 1) x hmus`
//!   partial-sum words per row between macros.  Reported as
//!   [`DataflowTrace::hop_words`] but *not* priced here — the fleet
//!   executor already charges them via `EnergyAccount::transfer_fj`
//!   (`[fleet] hop_energy_fj`).

use super::hierarchy::{
    MemoryHierarchy, ACC_RF, ACT_SRAM, CELL_GROUP, DRAM, NUM_LEVELS, WEIGHT_SRAM,
};
use crate::sched::plan::{LayerPlacement, LayerPlan};
use crate::spec::MacroSpec;

/// Word traffic through one memory level.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LevelAccess {
    pub reads: u64,
    pub writes: u64,
}

/// One layer call's movement trace: per-level access counts and their
/// priced femtojoule terms.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DataflowTrace {
    /// Per-level word traffic, [`super::hierarchy::LEVEL_NAMES`] order.
    pub access: [LevelAccess; NUM_LEVELS],
    /// Priced movement per level, femtojoules.
    pub movement_fj: [f64; NUM_LEVELS],
    /// Partial-sum words that crossed a macro boundary (split-K reduce;
    /// priced by the fleet's `transfer_fj`, not here).
    pub hop_words: u64,
}

impl DataflowTrace {
    /// Total priced movement, femtojoules.
    pub fn total_fj(&self) -> f64 {
        self.movement_fj.iter().sum()
    }
}

/// Tile geometry of one GEMM — the subset of [`LayerPlan`] the walk
/// needs, so the gateway can price a layer from graph shapes alone
/// (no packed weights).
struct Geom {
    n: usize,
    k: usize,
    nt: usize,
    kt: usize,
    n_pad: usize,
    k_pad: usize,
}

/// Walk one layer call: `m` activation rows through `plan`'s tiles,
/// placed by `placement` when running on a fleet (`None` = single
/// macro: one replica, no hops).
pub fn trace_layer(
    m: usize,
    plan: &LayerPlan,
    placement: Option<&LayerPlacement>,
    hier: &MemoryHierarchy,
) -> DataflowTrace {
    let g = Geom {
        n: plan.n,
        k: plan.k,
        nt: plan.nt,
        kt: plan.kt,
        n_pad: plan.n_pad,
        k_pad: plan.k_pad,
    };
    trace_geom(m, &g, &plan.spec, placement, hier)
}

/// [`trace_layer`] from raw GEMM dimensions — derives the tile geometry
/// with the same formulas as `sched::plan::LayerPlan::build`
/// (`kt = ceil(k / cols)`, `nt = ceil(n / hmus)`, padded to whole
/// tiles), so it prices exactly what the executor would without
/// needing packed weights.  `GET /v2/energy` traces one inference from
/// graph shapes through this entry point.
pub fn trace_dims(
    m: usize,
    n: usize,
    k: usize,
    sp: &MacroSpec,
    placement: Option<&LayerPlacement>,
    hier: &MemoryHierarchy,
) -> DataflowTrace {
    let kt = k.div_ceil(sp.cols).max(1);
    let nt = n.div_ceil(sp.hmus).max(1);
    let g = Geom { n, k, nt, kt, n_pad: nt * sp.hmus, k_pad: kt * sp.cols };
    trace_geom(m, &g, sp, placement, hier)
}

fn trace_geom(
    m: usize,
    geom: &Geom,
    sp: &MacroSpec,
    placement: Option<&LayerPlacement>,
    hier: &MemoryHierarchy,
) -> DataflowTrace {
    let m = m as u64;
    let (kt, nt) = (geom.kt as u64, geom.nt as u64);
    let (k, n) = (geom.k as u64, geom.n as u64);
    let (k_pad, n_pad) = (geom.k_pad as u64, geom.n_pad as u64);
    let tile_words = (sp.hmus * sp.cols) as u64;
    let tiles = nt * kt;
    let replicas = placement.map(|p| p.replicas as u64).unwrap_or(1);

    let mut access = [LevelAccess::default(); NUM_LEVELS];
    // weight fill: DRAM -> weight SRAM once, SRAM -> each replica's cells
    access[DRAM].reads += tiles * tile_words;
    access[WEIGHT_SRAM].writes += tiles * tile_words;
    access[WEIGHT_SRAM].reads += tiles * tile_words * replicas;
    access[CELL_GROUP].writes += tiles * tile_words * replicas;
    // weight-stationary reuse: every row re-reads every resident tile
    access[CELL_GROUP].reads += m * tiles * tile_words;
    // input broadcast: DRAM -> act SRAM, then one padded read per row
    access[DRAM].reads += m * k;
    access[ACT_SRAM].writes += m * k_pad;
    access[ACT_SRAM].reads += m * k_pad;
    // partial-sum accumulation + writeback
    access[ACC_RF].writes += m * n_pad * kt;
    access[ACC_RF].reads += m * n_pad * kt;
    access[ACT_SRAM].writes += m * n_pad;
    access[DRAM].writes += m * n;

    let hop_words = placement
        .map(|p| {
            let spans: u64 = (0..p.nt).map(|ni| (p.k_span(ni) - 1) as u64).sum();
            m * spans * sp.hmus as u64
        })
        .unwrap_or(0);

    let mut movement_fj = [0.0; NUM_LEVELS];
    for (i, fj) in movement_fj.iter_mut().enumerate() {
        let lv = hier.level(i);
        *fj = access[i].reads as f64 * lv.read_fj + access[i].writes as f64 * lv.write_fj;
    }
    DataflowTrace { access, movement_fj, hop_words }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::plan::{FleetDims, PlacementMode};
    use crate::spec::MacroSpec;
    use crate::util::prng::SplitMix64;

    fn plan_of(n: usize, k: usize) -> LayerPlan {
        let mut g = SplitMix64::new(21);
        let w: Vec<i32> = (0..n * k).map(|_| g.next_range_i32(-128, 128)).collect();
        LayerPlan::build(&w, n, k, 0, MacroSpec::default()).unwrap()
    }

    #[test]
    fn counts_follow_the_derivation() {
        let sp = MacroSpec::default();
        let (m, n, k) = (10usize, 20usize, 300usize);
        let plan = plan_of(n, k);
        let h = MemoryHierarchy::default();
        let t = trace_layer(m, &plan, None, &h);
        let tile_words = (sp.hmus * sp.cols) as u64;
        let tiles = (plan.nt * plan.kt) as u64;
        assert_eq!(t.access[WEIGHT_SRAM].writes, tiles * tile_words);
        assert_eq!(t.access[WEIGHT_SRAM].reads, tiles * tile_words, "one replica");
        assert_eq!(t.access[CELL_GROUP].reads, m as u64 * tiles * tile_words);
        assert_eq!(t.access[ACT_SRAM].reads, (m * plan.k_pad) as u64);
        assert_eq!(t.access[ACC_RF].writes, (m * plan.n_pad * plan.kt) as u64);
        assert_eq!(
            t.access[DRAM].reads,
            tiles * tile_words + (m * k) as u64
        );
        assert_eq!(t.access[DRAM].writes, (m * n) as u64);
        assert_eq!(t.hop_words, 0, "no placement, no hops");
        // cell reads are counted but priced at the default 0 fJ
        assert_eq!(t.movement_fj[CELL_GROUP], t.access[CELL_GROUP].writes as f64 * 1.9);
        assert!(t.total_fj() > 0.0);
    }

    #[test]
    fn trace_is_deterministic_and_pure() {
        let plan = plan_of(16, 400);
        let h = MemoryHierarchy::default();
        let a = trace_layer(32, &plan, None, &h);
        let b = trace_layer(32, &plan, None, &h);
        assert_eq!(a, b);
        for (x, y) in a.movement_fj.iter().zip(&b.movement_fj) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn split_k_placement_reports_hop_words_matching_fleet_accounting() {
        // kt = 3 > residency 1 -> split-K; hop words must equal the
        // fleet executor's transfer formula m * sum(span-1) * hmus
        let sp = MacroSpec::default();
        let (m, n, k) = (8usize, 16usize, 3 * sp.cols);
        let plan = plan_of(n, k);
        let lp = LayerPlacement::plan(
            0,
            plan.nt,
            plan.kt,
            plan.nt * plan.kt,
            FleetDims { macros: 4, residency_tiles: 1 },
            PlacementMode::Auto,
        );
        assert!(lp.split_k());
        let h = MemoryHierarchy::default();
        let t = trace_layer(m, &plan, Some(&lp), &h);
        let spans: u64 = (0..lp.nt).map(|ni| (lp.k_span(ni) - 1) as u64).sum();
        assert_eq!(t.hop_words, m as u64 * spans * sp.hmus as u64);
        assert!(t.hop_words > 0);
    }

    #[test]
    fn trace_dims_matches_trace_layer() {
        // the weights-free entry point must price exactly what the
        // packed plan does — GET /v2/energy depends on this identity
        let sp = MacroSpec::default();
        for (m, n, k) in [(1usize, 8usize, 27usize), (64, 20, 300), (16, 144, 3 * sp.cols)] {
            let plan = plan_of(n, k);
            let h = MemoryHierarchy::default();
            let a = trace_layer(m, &plan, None, &h);
            let b = trace_dims(m, n, k, &sp, None, &h);
            assert_eq!(a, b);
            for (x, y) in a.movement_fj.iter().zip(&b.movement_fj) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn replication_scales_fill_but_not_streaming() {
        let plan = plan_of(8, 100); // 1 tile -> replicates across a fleet
        let lp = LayerPlacement::plan(
            0,
            plan.nt,
            plan.kt,
            plan.nt * plan.kt,
            FleetDims { macros: 4, residency_tiles: 4 },
            PlacementMode::Replicate,
        );
        assert!(lp.replicas > 1);
        let h = MemoryHierarchy::default();
        let single = trace_layer(64, &plan, None, &h);
        let fleet = trace_layer(64, &plan, Some(&lp), &h);
        // each replica's cell array gets its own fill...
        assert_eq!(
            fleet.access[CELL_GROUP].writes,
            single.access[CELL_GROUP].writes * lp.replicas as u64
        );
        // ...but the activation stream and DRAM traffic do not replicate
        assert_eq!(fleet.access[ACT_SRAM], single.access[ACT_SRAM]);
        assert_eq!(fleet.access[DRAM], single.access[DRAM]);
        assert_eq!(fleet.hop_words, 0, "replication alone never hops");
    }
}
