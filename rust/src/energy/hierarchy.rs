//! Declarative memory-hierarchy description (`[hardware]`, DESIGN.md
//! §15) — the zigzag-imc production shape: the stack is *data*, not
//! code, so swapping the 65 nm / 0.6 V anchor numbers for another
//! process corner is a config edit.
//!
//! Five levels model the paper's macro plus the system around it:
//!
//! | level         | holds                                  |
//! |---------------|----------------------------------------|
//! | `cell_group`  | split-port 6T array (one packed tile)  |
//! | `acc_rf`      | per-HMU partial-sum accumulation RF    |
//! | `weight_sram` | on-chip weight buffer feeding the array|
//! | `act_sram`    | on-chip activation buffer              |
//! | `dram`        | off-chip backing store                 |
//!
//! A *word* is one 8-bit operand (weight, activation, or partial-sum
//! lane), so `size_bytes` and word counts share a unit.  Cell reads are
//! already folded into `EnergyParams::e_dat_bitmac_fj`, so the default
//! `cell_group` read energy is 0 — the dataflow walker still *counts*
//! those reads (the weight-stationary reuse statistic) without
//! double-pricing them.
//!
//! In TOML each level is one array, `[size_bytes, read_fj_per_word,
//! write_fj_per_word, bandwidth_words_per_cycle, ports]`:
//!
//! ```toml
//! [hardware]
//! model = "hierarchy"
//! weight_sram = [73728, 5.8, 7.2, 16, 1]
//! ```

use anyhow::{bail, Result};

/// Memory levels in the stack, innermost first.
pub const NUM_LEVELS: usize = 5;

/// Index of the split-port 6T cell array level.
pub const CELL_GROUP: usize = 0;
/// Index of the partial-sum accumulation register file level.
pub const ACC_RF: usize = 1;
/// Index of the on-chip weight SRAM level.
pub const WEIGHT_SRAM: usize = 2;
/// Index of the on-chip activation SRAM level.
pub const ACT_SRAM: usize = 3;
/// Index of the off-chip DRAM level.
pub const DRAM: usize = 4;

/// Level names, in index order — also the `[hardware]` TOML keys and
/// the `level` label values in Prometheus / `GET /v2/energy`.
pub const LEVEL_NAMES: [&str; NUM_LEVELS] =
    ["cell_group", "acc_rf", "weight_sram", "act_sram", "dram"];

/// The compact (per-op constants) cost model name.
pub const MODEL_COMPACT: &str = "compact";
/// The hierarchy-and-dataflow cost model name.
pub const MODEL_HIERARCHY: &str = "hierarchy";

/// One level of the memory stack.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryLevel {
    /// Capacity in bytes (= 8-bit words).
    pub size_bytes: u64,
    /// Energy per word read, femtojoules.
    pub read_fj: f64,
    /// Energy per word written, femtojoules.
    pub write_fj: f64,
    /// Sustained bandwidth in words per analog-clock cycle.
    pub bandwidth_words: f64,
    /// Concurrent access ports.
    pub ports: u32,
}

impl MemoryLevel {
    /// Parse one level from its TOML array form
    /// `[size_bytes, read_fj, write_fj, bandwidth_words, ports]`.
    /// `key` names the field in errors (e.g. `hardware.weight_sram`).
    pub fn from_array(key: &str, vals: &[f64]) -> Result<Self> {
        if vals.len() != 5 {
            bail!(
                "{key}: expected [size_bytes, read_fj, write_fj, bandwidth_words, ports] \
                 (5 entries), got {}",
                vals.len()
            );
        }
        if vals[0] < 0.0 || vals[4] < 0.0 {
            bail!("{key}: size_bytes and ports must be non-negative, got {vals:?}");
        }
        Ok(Self {
            size_bytes: vals[0] as u64,
            read_fj: vals[1],
            write_fj: vals[2],
            bandwidth_words: vals[3],
            ports: vals[4] as u32,
        })
    }
}

/// The declarative memory stack (`[hardware]` in TOML).
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryHierarchy {
    /// Levels in [`LEVEL_NAMES`] order.
    pub levels: [MemoryLevel; NUM_LEVELS],
}

impl Default for MemoryHierarchy {
    /// 65 nm / 0.6 V anchor stack.  SRAM per-word energies sit between
    /// the per-bit MAC constant (10.5 fJ) and the ADC conversion
    /// (1320 fJ); DRAM is the usual two orders of magnitude above
    /// on-chip SRAM.  `cell_group` reads are priced at 0 because they
    /// are folded into `e_dat_bitmac_fj` (module docs).
    fn default() -> Self {
        Self {
            levels: [
                // cell_group: one packed 64x144 tile, split-port (2R/W)
                MemoryLevel {
                    size_bytes: 1_152,
                    read_fj: 0.0,
                    write_fj: 1.9,
                    bandwidth_words: 144.0,
                    ports: 2,
                },
                // acc_rf: 8 HMUs x 32 B partial-sum lanes
                MemoryLevel {
                    size_bytes: 256,
                    read_fj: 1.1,
                    write_fj: 1.3,
                    bandwidth_words: 16.0,
                    ports: 2,
                },
                // weight_sram: 72 KiB (64 resident tiles)
                MemoryLevel {
                    size_bytes: 73_728,
                    read_fj: 5.8,
                    write_fj: 7.2,
                    bandwidth_words: 16.0,
                    ports: 1,
                },
                // act_sram: 36 KiB double-buffered activation store
                MemoryLevel {
                    size_bytes: 36_864,
                    read_fj: 5.2,
                    write_fj: 6.4,
                    bandwidth_words: 16.0,
                    ports: 1,
                },
                // dram: 64 MiB off-chip
                MemoryLevel {
                    size_bytes: 64 * 1024 * 1024,
                    read_fj: 620.0,
                    write_fj: 640.0,
                    bandwidth_words: 4.0,
                    ports: 1,
                },
            ],
        }
    }
}

impl MemoryHierarchy {
    /// The level at `idx` (see the index constants).
    #[inline]
    pub fn level(&self, idx: usize) -> &MemoryLevel {
        &self.levels[idx]
    }

    /// Validate every level with field-named errors.  `tile_bytes` is
    /// one packed weight tile (`sched::fleet::tile_bytes`): any level
    /// that stages whole weight tiles (cell group, weight SRAM, DRAM)
    /// must be able to hold at least one.
    pub fn validate(&self, tile_bytes: u64) -> Result<()> {
        for (i, lv) in self.levels.iter().enumerate() {
            let key = LEVEL_NAMES[i];
            if lv.size_bytes == 0 {
                bail!("hardware.{key}: size_bytes must be >= 1");
            }
            for (field, v) in [("read_fj", lv.read_fj), ("write_fj", lv.write_fj)] {
                if v.is_nan() || v < 0.0 {
                    bail!("hardware.{key}: {field} must be finite and >= 0 fJ, got {v}");
                }
            }
            if lv.bandwidth_words.is_nan() || lv.bandwidth_words <= 0.0 {
                bail!(
                    "hardware.{key}: bandwidth_words must be > 0, got {}",
                    lv.bandwidth_words
                );
            }
            if lv.ports == 0 {
                bail!("hardware.{key}: ports must be >= 1");
            }
        }
        for idx in [CELL_GROUP, WEIGHT_SRAM, DRAM] {
            if self.levels[idx].size_bytes < tile_bytes {
                bail!(
                    "hardware.{}: size_bytes {} cannot hold one packed weight tile \
                     ({tile_bytes} B)",
                    LEVEL_NAMES[idx],
                    self.levels[idx].size_bytes
                );
            }
        }
        Ok(())
    }
}

/// Validate a `[hardware] model` string.
pub fn validate_model(name: &str) -> Result<()> {
    if name != MODEL_COMPACT && name != MODEL_HIERARCHY {
        bail!(
            "hardware.model: unknown model {name:?} ({MODEL_COMPACT:?}|{MODEL_HIERARCHY:?})"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const TILE: u64 = 1_152;

    #[test]
    fn default_stack_is_valid() {
        let h = MemoryHierarchy::default();
        h.validate(TILE).unwrap();
        // ordering sanity: moving outward gets more capacious and more
        // expensive per word
        assert!(h.level(WEIGHT_SRAM).size_bytes > h.level(CELL_GROUP).size_bytes);
        assert!(h.level(DRAM).read_fj > h.level(WEIGHT_SRAM).read_fj);
        assert!(h.level(WEIGHT_SRAM).read_fj > h.level(ACC_RF).read_fj);
    }

    #[test]
    fn from_array_round_trips() {
        let lv = MemoryLevel::from_array("hardware.x", &[1024.0, 2.0, 3.0, 16.0, 2.0]).unwrap();
        assert_eq!(lv.size_bytes, 1024);
        assert_eq!(lv.read_fj, 2.0);
        assert_eq!(lv.write_fj, 3.0);
        assert_eq!(lv.bandwidth_words, 16.0);
        assert_eq!(lv.ports, 2);
        let err = MemoryLevel::from_array("hardware.x", &[1.0, 2.0]).unwrap_err();
        assert!(err.to_string().contains("hardware.x"), "{err}");
        assert!(MemoryLevel::from_array("hardware.x", &[-1.0, 2.0, 3.0, 16.0, 1.0]).is_err());
    }

    #[test]
    fn validate_rejects_bad_levels_with_field_names() {
        let mut h = MemoryHierarchy::default();
        h.levels[ACT_SRAM].size_bytes = 0;
        let err = h.validate(TILE).unwrap_err();
        assert!(err.to_string().contains("hardware.act_sram"), "{err}");

        let mut h = MemoryHierarchy::default();
        h.levels[ACC_RF].read_fj = -1.0;
        let err = h.validate(TILE).unwrap_err();
        assert!(err.to_string().contains("hardware.acc_rf"), "{err}");

        let mut h = MemoryHierarchy::default();
        h.levels[DRAM].bandwidth_words = 0.0;
        let err = h.validate(TILE).unwrap_err();
        assert!(err.to_string().contains("hardware.dram"), "{err}");

        let mut h = MemoryHierarchy::default();
        h.levels[CELL_GROUP].ports = 0;
        let err = h.validate(TILE).unwrap_err();
        assert!(err.to_string().contains("hardware.cell_group"), "{err}");

        // a NaN energy must not sneak past the >= 0 check
        let mut h = MemoryHierarchy::default();
        h.levels[WEIGHT_SRAM].write_fj = f64::NAN;
        assert!(h.validate(TILE).is_err());
    }

    #[test]
    fn tile_holding_levels_must_fit_one_tile() {
        for idx in [CELL_GROUP, WEIGHT_SRAM, DRAM] {
            let mut h = MemoryHierarchy::default();
            h.levels[idx].size_bytes = TILE - 1;
            let err = h.validate(TILE).unwrap_err();
            assert!(
                err.to_string().contains(LEVEL_NAMES[idx])
                    && err.to_string().contains("packed weight tile"),
                "{err}"
            );
        }
        // act_sram / acc_rf hold words, not tiles: small is fine
        let mut h = MemoryHierarchy::default();
        h.levels[ACC_RF].size_bytes = 16;
        h.validate(TILE).unwrap();
    }

    #[test]
    fn model_names_validate() {
        validate_model(MODEL_COMPACT).unwrap();
        validate_model(MODEL_HIERARCHY).unwrap();
        let err = validate_model("zigzag").unwrap_err();
        assert!(err.to_string().contains("hardware.model"), "{err}");
    }
}
