//! Energy / area / latency model (DESIGN.md §4).
//!
//! Per-component constants are calibrated so the model reproduces the
//! paper's reported *ratios* on the 65 nm / 0.6 V operating point:
//!
//! * DCIM baseline efficiency ≈ 2.97 TOPS/W (= 5.79 / 1.95, Fig 9)
//! * HCIM (fixed B=8) = 1.56x DCIM (§VI)
//! * OSA-HCIM up to 1.95x DCIM, 5.33–5.79 TOPS/W (§VI, Table I)
//! * ADC ≈ 17 % of power, 6 % of area; OSE ≈ 1 % / 1 % (Fig 7)
//!
//! The `calibration` test in this module asserts the anchors; the
//! `fig7`/`fig9` harnesses print the full breakdowns.
//!
//! PR 9 layers a second cost model on top of these per-op constants:
//! [`hierarchy`] declares the memory stack (`[hardware]` in TOML) and
//! [`dataflow`] walks a layer plan's tiles to price every word of data
//! movement into [`EnergyBreakdown::movement_fj`].  The per-op path
//! stays the default (`model = "compact"`) and is bit-identical to the
//! pre-PR numbers — `movement_fj` is all-zero there, and `x + 0.0`
//! preserves every f64 bit for the non-negative sums involved.

pub mod dataflow;
pub mod hierarchy;

use crate::macrosim::OpCounts;
use crate::spec::MacroSpec;
use hierarchy::NUM_LEVELS;

/// Analog-domain clock (SAR ADC cadence); the DAT runs at 2x this.
pub const CLK_ANALOG_HZ: f64 = 100.0e6;

/// Per-component energy constants, femtojoules (65 nm, 0.6 V).
#[derive(Debug, Clone, Copy)]
pub struct EnergyParams {
    /// Digital 1-bit MAC per column per HMU (cell read + D_MULT + DAT
    /// share).
    pub e_dat_bitmac_fj: f64,
    /// One 3-bit SAR conversion (per HMU per analog group).
    pub e_adc_conv_fj: f64,
    /// DAC drive + charge share per column per analog group (GBL is
    /// shared by the 8 HMUs, so this is *not* per HMU).
    pub e_dac_col_fj: f64,
    /// N/Q compression per HMU per SE pair.
    pub e_nq_fj: f64,
    /// OSE accumulate + threshold compare per macro op (amortized over
    /// the 8 HMUs — the paper's "compressed DMAC bandwidth").
    pub e_ose_op_fj: f64,
    /// Controller + IO per macro op.
    pub e_ctrl_op_fj: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self {
            e_dat_bitmac_fj: 10.5,
            e_adc_conv_fj: 1_320.0,
            e_dac_col_fj: 55.0,
            e_nq_fj: 45.0,
            e_ose_op_fj: 3_600.0,
            e_ctrl_op_fj: 2_000.0,
        }
    }
}

/// Energy of one macro op split by component, femtojoules.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub digital_fj: f64,
    pub adc_fj: f64,
    pub dac_fj: f64,
    pub nq_fj: f64,
    pub ose_fj: f64,
    pub ctrl_fj: f64,
    /// Data-movement energy per memory level ([`hierarchy`] order:
    /// cell group, accumulation RF, weight SRAM, activation SRAM,
    /// DRAM), femtojoules.  All-zero under the `compact` model; filled
    /// by [`dataflow::trace_layer`] under `model = "hierarchy"`.
    pub movement_fj: [f64; NUM_LEVELS],
}

impl EnergyBreakdown {
    pub fn total_fj(&self) -> f64 {
        self.digital_fj
            + self.adc_fj
            + self.dac_fj
            + self.nq_fj
            + self.ose_fj
            + self.ctrl_fj
            + self.movement_total_fj()
    }

    /// Total data-movement energy across every memory level, femtojoules.
    pub fn movement_total_fj(&self) -> f64 {
        self.movement_fj.iter().sum()
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.digital_fj += other.digital_fj;
        self.adc_fj += other.adc_fj;
        self.dac_fj += other.dac_fj;
        self.nq_fj += other.nq_fj;
        self.ose_fj += other.ose_fj;
        self.ctrl_fj += other.ctrl_fj;
        for (acc, v) in self.movement_fj.iter_mut().zip(&other.movement_fj) {
            *acc += v;
        }
    }

    /// Fractions per *macro* component (sums to 1 when total > 0).
    /// Movement stays out so the Fig 7 component shares remain a
    /// property of the macro alone; read it via
    /// [`EnergyBreakdown::movement_fj`] / [`hierarchy::LEVEL_NAMES`].
    pub fn fractions(&self) -> [(&'static str, f64); 6] {
        let t = (self.digital_fj + self.adc_fj + self.dac_fj + self.nq_fj + self.ose_fj
            + self.ctrl_fj)
            .max(1e-12);
        [
            ("DAT+array (digital)", self.digital_fj / t),
            ("SAR ADC", self.adc_fj / t),
            ("DAC+AIN (analog drive)", self.dac_fj / t),
            ("N/Q", self.nq_fj / t),
            ("OSE", self.ose_fj / t),
            ("Ctrl+IO", self.ctrl_fj / t),
        ]
    }
}

impl EnergyParams {
    /// Energy of one macro op with the given workload counts.
    /// `with_se` adds the SE-mode N/Q + OSE overhead (OSA mode).
    pub fn op_energy(&self, c: &OpCounts, with_se: bool, sp: &MacroSpec) -> EnergyBreakdown {
        let pair = self.e_dat_bitmac_fj * sp.cols as f64 * sp.hmus as f64;
        let mut b = EnergyBreakdown {
            // SE pairs are digital pairs; when with_se they are already
            // included in digital_pairs (reused in computing mode).
            digital_fj: c.digital_pairs as f64 * pair,
            adc_fj: c.adc_groups as f64 * sp.hmus as f64 * self.e_adc_conv_fj,
            dac_fj: c.adc_groups as f64 * sp.cols as f64 * self.e_dac_col_fj,
            ctrl_fj: self.e_ctrl_op_fj,
            ..Default::default()
        };
        if with_se {
            b.nq_fj = c.se_pairs as f64 * sp.hmus as f64 * self.e_nq_fj;
            b.ose_fj = self.e_ose_op_fj;
        }
        b
    }

    /// Ops per macro op under the paper's normalization
    /// (1 8b x 8b MAC = 2 OPs; a macro op performs hmus*cols MACs).
    pub fn ops_per_macro_op(&self, sp: &MacroSpec) -> f64 {
        2.0 * sp.hmus as f64 * sp.cols as f64
    }

    /// TOPS/W for a uniform stream of ops with the given breakdown.
    pub fn tops_per_watt(&self, per_op: &EnergyBreakdown, sp: &MacroSpec) -> f64 {
        let joules = per_op.total_fj() * 1e-15;
        self.ops_per_macro_op(sp) / joules / 1e12
    }
}

/// Streaming accumulator used by the scheduler / coordinator metrics.
#[derive(Debug, Clone, Default)]
pub struct EnergyAccount {
    pub breakdown: EnergyBreakdown,
    pub macro_ops: u64,
    pub cycles: u64,
    /// Inter-macro partial-sum transfer energy (fleet split-K reduce),
    /// femtojoules.  Kept outside [`EnergyBreakdown`] so the macro-level
    /// component fractions (Fig 7 calibration) stay a property of the
    /// macro alone; included in [`EnergyAccount::total_energy_j`].
    pub transfer_fj: f64,
    /// Partial sums that crossed a macro boundary (one hop each).
    pub transfer_hops: u64,
    /// Per-macro cycle attribution when executed on a macro fleet
    /// (empty = single-macro execution; index = macro id).  The fleet's
    /// modeled latency is the critical path, [`EnergyAccount::fleet_seconds`].
    pub macro_cycles: Vec<u64>,
}

impl EnergyAccount {
    pub fn record(&mut self, b: &EnergyBreakdown, counts: &OpCounts) {
        self.breakdown.add(b);
        self.macro_ops += 1;
        self.cycles += counts.total_cycles() as u64;
    }

    pub fn merge(&mut self, other: &EnergyAccount) {
        self.breakdown.add(&other.breakdown);
        self.macro_ops += other.macro_ops;
        self.cycles += other.cycles;
        self.transfer_fj += other.transfer_fj;
        self.transfer_hops += other.transfer_hops;
        if !other.macro_cycles.is_empty() {
            if self.macro_cycles.len() < other.macro_cycles.len() {
                self.macro_cycles.resize(other.macro_cycles.len(), 0);
            }
            for (acc, &c) in self.macro_cycles.iter_mut().zip(&other.macro_cycles) {
                *acc += c;
            }
        }
    }

    pub fn total_energy_j(&self) -> f64 {
        (self.breakdown.total_fj() + self.transfer_fj) * 1e-15
    }

    /// Fraction of total modeled energy spent on inter-macro transfers
    /// (0.0 on a single macro).
    pub fn transfer_fraction(&self) -> f64 {
        let total = self.breakdown.total_fj() + self.transfer_fj;
        if total <= 0.0 {
            0.0
        } else {
            self.transfer_fj / total
        }
    }

    /// Modeled wall-clock of a fleet execution: the slowest macro's
    /// cycle count (critical path).  Falls back to the aggregate
    /// [`EnergyAccount::seconds`] when no per-macro attribution exists.
    pub fn fleet_seconds(&self) -> f64 {
        match self.macro_cycles.iter().max() {
            Some(&c) if c > 0 => c as f64 / CLK_ANALOG_HZ,
            _ => self.seconds(),
        }
    }

    pub fn tops_per_watt(&self, sp: &MacroSpec) -> f64 {
        if self.macro_ops == 0 {
            return 0.0;
        }
        let ops = 2.0 * sp.hmus as f64 * sp.cols as f64 * self.macro_ops as f64;
        ops / self.total_energy_j() / 1e12
    }

    /// Wall-clock seconds of macro time at the analog clock.
    pub fn seconds(&self) -> f64 {
        self.cycles as f64 / CLK_ANALOG_HZ
    }

    /// Average power in watts over the modeled execution.
    pub fn watts(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.total_energy_j() / self.seconds()
    }
}

/// Component areas, square micrometres (65 nm, modeled — Fig 6/7).
#[derive(Debug, Clone, Copy)]
pub struct AreaParams {
    pub array_um2: f64,
    pub dat_um2: f64,
    pub adc_um2: f64,
    pub dac_um2: f64,
    pub nq_um2: f64,
    pub ose_um2: f64,
    pub ctrl_um2: f64,
}

impl Default for AreaParams {
    fn default() -> Self {
        // 9216 split-port 6T cells (~2.0 um^2 each at 65nm) + periphery,
        // proportioned to reproduce Fig 7's area shares
        // (ADC 6 %, OSE 1 %).
        Self {
            array_um2: 18_400.0,
            dat_um2: 19_900.0,
            adc_um2: 3_150.0,
            dac_um2: 4_700.0,
            nq_um2: 1_050.0,
            ose_um2: 520.0,
            ctrl_um2: 4_780.0,
        }
    }
}

impl AreaParams {
    pub fn total_um2(&self) -> f64 {
        self.array_um2 + self.dat_um2 + self.adc_um2 + self.dac_um2 + self.nq_um2
            + self.ose_um2 + self.ctrl_um2
    }

    pub fn fractions(&self) -> [(&'static str, f64); 7] {
        let t = self.total_um2();
        [
            ("SRAM array", self.array_um2 / t),
            ("DAT", self.dat_um2 / t),
            ("SAR ADC", self.adc_um2 / t),
            ("DAC+AIN", self.dac_um2 / t),
            ("N/Q", self.nq_um2 / t),
            ("OSE", self.ose_um2 / t),
            ("Ctrl+IO", self.ctrl_um2 / t),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::macrosim::counts_for_boundary;

    fn sp() -> MacroSpec {
        MacroSpec::default()
    }

    #[test]
    fn calibration_dcim_baseline() {
        // DCIM ≈ 2.97 TOPS/W (Fig 9 anchor: 5.79 / 1.95)
        let p = EnergyParams::default();
        let c = counts_for_boundary(0, false, &sp());
        let e = p.op_energy(&c, false, &sp());
        let tw = p.tops_per_watt(&e, &sp());
        assert!(
            (tw - 2.97).abs() / 2.97 < 0.10,
            "DCIM {tw:.3} TOPS/W, expected ≈2.97"
        );
    }

    #[test]
    fn calibration_hcim_ratio() {
        // HCIM (fixed B=8, no OSE) = 1.56x DCIM (§VI)
        let p = EnergyParams::default();
        let d = p.op_energy(&counts_for_boundary(0, false, &sp()), false, &sp());
        let h = p.op_energy(&counts_for_boundary(8, false, &sp()), false, &sp());
        let ratio = d.total_fj() / h.total_fj();
        assert!(
            (ratio - 1.56).abs() < 0.12,
            "HCIM ratio {ratio:.3}, expected ≈1.56"
        );
    }

    #[test]
    fn calibration_osa_reachable() {
        // An OSA mix dominated by B in {9, 10} must exceed 1.9x DCIM.
        let p = EnergyParams::default();
        let s = sp();
        let d = p.op_energy(&counts_for_boundary(0, false, &s), false, &s).total_fj();
        // Deep-layer-like mix (paper Fig 8b: low precision dominates with
        // depth); the Fig 9 harness derives the real mix from the OSE.
        let mix = [(5, 0.02), (6, 0.03), (7, 0.05), (8, 0.10), (9, 0.20), (10, 0.60)];
        let mut e = 0.0;
        for (b, w) in mix {
            let c = counts_for_boundary(b, true, &s);
            e += w * p.op_energy(&c, true, &s).total_fj();
        }
        let ratio = d / e;
        assert!(ratio > 1.90, "OSA mix ratio {ratio:.3}, expected > 1.90");
        assert!(ratio < 2.4, "OSA mix ratio {ratio:.3} implausibly high");
    }

    #[test]
    fn calibration_adc_power_share() {
        // ADC ≈ 17 % of power at a typical hybrid operating point (Fig 7).
        let p = EnergyParams::default();
        let s = sp();
        let e = p.op_energy(&counts_for_boundary(8, true, &s), true, &s);
        let frac = e.adc_fj / e.total_fj();
        assert!(
            (frac - 0.17).abs() < 0.05,
            "ADC power share {frac:.3}, expected ≈0.17"
        );
    }

    #[test]
    fn calibration_ose_overhead_small() {
        // OSE ≈ 1 % power (Fig 7): "minimal overhead".
        let p = EnergyParams::default();
        let s = sp();
        let e = p.op_energy(&counts_for_boundary(8, true, &s), true, &s);
        let frac = e.ose_fj / e.total_fj();
        assert!(frac < 0.02, "OSE power share {frac:.3}, expected ≈0.01");
        let a = AreaParams::default();
        let afrac = a.ose_um2 / a.total_um2();
        assert!(afrac < 0.02, "OSE area share {afrac:.3}");
    }

    #[test]
    fn calibration_adc_area_share() {
        let a = AreaParams::default();
        let frac = a.adc_um2 / a.total_um2();
        assert!((frac - 0.06).abs() < 0.02, "ADC area share {frac:.3}");
    }

    #[test]
    fn energy_monotone_in_boundary() {
        let p = EnergyParams::default();
        let s = sp();
        let mut prev = f64::INFINITY;
        for b in [5, 6, 7, 8, 9, 10] {
            let e = p.op_energy(&counts_for_boundary(b, true, &s), true, &s).total_fj();
            assert!(e < prev, "energy not decreasing at B={b}");
            prev = e;
        }
    }

    #[test]
    fn account_accumulates() {
        let p = EnergyParams::default();
        let s = sp();
        let c = counts_for_boundary(8, true, &s);
        let e = p.op_energy(&c, true, &s);
        let mut acc = EnergyAccount::default();
        acc.record(&e, &c);
        acc.record(&e, &c);
        assert_eq!(acc.macro_ops, 2);
        assert!((acc.breakdown.total_fj() - 2.0 * e.total_fj()).abs() < 1e-6);
        assert!(acc.tops_per_watt(&s) > 0.0);
        assert!(acc.watts() > 0.0);
        let mut acc2 = EnergyAccount::default();
        acc2.merge(&acc);
        assert_eq!(acc2.macro_ops, 2);
    }

    #[test]
    fn transfer_energy_accumulates_outside_breakdown() {
        let p = EnergyParams::default();
        let s = sp();
        let c = counts_for_boundary(8, true, &s);
        let e = p.op_energy(&c, true, &s);
        let mut acc = EnergyAccount::default();
        acc.record(&e, &c);
        let base_j = acc.total_energy_j();
        acc.transfer_fj += 1_000.0;
        acc.transfer_hops += 8;
        acc.macro_cycles = vec![10, 30, 20];
        assert!((acc.total_energy_j() - (base_j + 1_000.0e-15)).abs() < 1e-30);
        assert!(acc.transfer_fraction() > 0.0 && acc.transfer_fraction() < 1.0);
        // breakdown fractions stay macro-only: unaffected by transfer
        let sum: f64 = acc.breakdown.fractions().iter().map(|(_, f)| f).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        // critical path = slowest macro
        assert!((acc.fleet_seconds() - 30.0 / CLK_ANALOG_HZ).abs() < 1e-18);
        // merge adds transfer + elementwise macro cycles (with resize)
        let mut m = EnergyAccount::default();
        m.macro_cycles = vec![5];
        m.merge(&acc);
        m.merge(&acc);
        assert_eq!(m.transfer_hops, 16);
        assert_eq!(m.macro_cycles, vec![25, 60, 40]);
        // single-macro accounts fall back to the aggregate clock
        let single = EnergyAccount { cycles: 40, ..Default::default() };
        assert!((single.fleet_seconds() - single.seconds()).abs() < 1e-18);
    }

    #[test]
    fn fractions_sum_to_one() {
        let p = EnergyParams::default();
        let s = sp();
        let e = p.op_energy(&counts_for_boundary(8, true, &s), true, &s);
        let sum: f64 = e.fractions().iter().map(|(_, f)| f).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        let asum: f64 = AreaParams::default().fractions().iter().map(|(_, f)| f).sum();
        assert!((asum - 1.0).abs() < 1e-9);
    }
}
