//! Fixed-point quantization + bit-plane decomposition (paper Eq. 1).
//!
//! The macro decomposes a multi-bit MAC into 1-bit MACs:
//! `MAC(A, W) = sum_{i,j} s_i * 2^(i+j) * D[i][j]` with
//! `D[i][j] = sum_c w_bit[i][c] * a_bit[j][c]`, `s_i = -1` for the
//! two's-complement sign plane and `+1` otherwise.
//!
//! The hot path packs bit planes into u64 words so each 1-bit MAC over
//! 144 columns is 3 AND+POPCNT operations ([`PackedBits`]) — this is the
//! optimized equivalent of the 144-column adder tree.

use crate::spec::MacroSpec;

/// Sign of weight plane `i` under two's complement.
#[inline]
pub fn plane_sign(i: usize, w_bits: usize) -> i32 {
    if i == w_bits - 1 {
        -1
    } else {
        1
    }
}

/// Bit `j` of a uint activation.
#[inline]
pub fn act_bit(a: i32, j: usize) -> i32 {
    (a >> j) & 1
}

/// Bit `i` of the two's-complement encoding of an int weight.
#[inline]
pub fn weight_bit(w: i32, i: usize, w_bits: usize) -> i32 {
    ((w & ((1 << w_bits) - 1)) >> i) & 1
}

/// Quantize a float to i32 with round-half-up (`floor(x/s + 0.5)`),
/// clamped to `[lo, hi]` — matches `model.quant_round` exactly.
#[inline]
pub fn quantize_clamped(x: f32, scale: f32, lo: i32, hi: i32) -> i32 {
    let q = (x / scale + 0.5).floor() as i32;
    q.clamp(lo, hi)
}

/// uint8 activation quantization (clamp at 0 doubles as ReLU).
#[inline]
pub fn quantize_act(x: f32, scale: f32) -> i32 {
    quantize_clamped(x, scale, 0, 255)
}

/// One row's bit planes packed into u64 words (LSB-first bit order
/// within a word; column c lives in word c/64, bit c%64).
#[derive(Debug, Clone)]
pub struct PackedBits {
    /// planes[p * words + w]
    words: Vec<u64>,
    /// bit p set when plane p has at least one 1 (sparsity fast path:
    /// high activation planes are often all-zero, letting the hybrid
    /// datapath skip those 1-bit MACs entirely)
    nonzero: u16,
    pub n_planes: usize,
    pub n_words: usize,
    pub n_cols: usize,
}

impl PackedBits {
    /// Pack the bit planes of one integer vector.
    /// `signed_bits` selects two's-complement masking for weights.
    pub fn pack(values: &[i32], n_planes: usize, signed_bits: bool) -> Self {
        let n_cols = values.len();
        let n_words = n_cols.div_ceil(64);
        let mut words = vec![0u64; n_planes * n_words];
        let mask = (1i64 << n_planes) - 1;
        for (c, &v) in values.iter().enumerate() {
            let bits = if signed_bits { (v as i64) & mask } else { v as i64 };
            debug_assert!(
                signed_bits || (0..=mask).contains(&bits),
                "activation {v} out of range for {n_planes} planes"
            );
            let (wi, bi) = (c / 64, c % 64);
            for p in 0..n_planes {
                if (bits >> p) & 1 == 1 {
                    words[p * n_words + wi] |= 1u64 << bi;
                }
            }
        }
        let mut nonzero = 0u16;
        for p in 0..n_planes {
            if words[p * n_words..(p + 1) * n_words].iter().any(|&w| w != 0) {
                nonzero |= 1 << p;
            }
        }
        Self { words, nonzero, n_planes, n_words, n_cols }
    }

    /// True when plane `p` has no set bits (its 1-bit MACs are all 0).
    #[inline]
    pub fn plane_empty(&self, p: usize) -> bool {
        self.nonzero & (1 << p) == 0
    }

    /// The packed words of plane `p`.
    #[inline]
    pub fn plane(&self, p: usize) -> &[u64] {
        &self.words[p * self.n_words..(p + 1) * self.n_words]
    }

    /// 1-bit MAC: popcount(self.plane(p) & other.plane(q)).
    #[inline]
    pub fn and_popcount(&self, p: usize, other: &PackedBits, q: usize) -> i32 {
        debug_assert_eq!(self.n_words, other.n_words);
        and_popcount_words(self.plane(p), other.plane(q))
    }
}

/// Word-blocked 1-bit MAC over two pre-resolved plane slices: the inner
/// loop of the hot path, written over `u64` blocks with no index bounds
/// checks so callers can hoist the plane lookups (and the `plane_empty`
/// test) out of their per-HMU walk.
#[inline]
pub fn and_popcount_words(a: &[u64], b: &[u64]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x & y).count_ones()).sum::<u32>() as i32
}

/// All order partial sums `D[i][j]` for one (activation row, weight row)
/// pair — the naive reference the packed path is tested against.
pub fn order_partials_naive(a: &[i32], w: &[i32], sp: &MacroSpec) -> Vec<Vec<i32>> {
    assert_eq!(a.len(), w.len());
    let mut d = vec![vec![0i32; sp.a_bits]; sp.w_bits];
    for i in 0..sp.w_bits {
        for j in 0..sp.a_bits {
            let mut acc = 0;
            for c in 0..a.len() {
                acc += weight_bit(w[c], i, sp.w_bits) * act_bit(a[c], j);
            }
            d[i][j] = acc;
        }
    }
    d
}

/// Exact integer dot product (the DCIM ground truth).
pub fn exact_dot(a: &[i32], w: &[i32]) -> i32 {
    a.iter().zip(w).map(|(&x, &y)| x * y).sum()
}

/// Recompose Eq. 1 from partials (test helper).
pub fn recompose(d: &[Vec<i32>], sp: &MacroSpec) -> i64 {
    let mut acc: i64 = 0;
    for i in 0..sp.w_bits {
        for j in 0..sp.a_bits {
            acc += plane_sign(i, sp.w_bits) as i64 * ((d[i][j] as i64) << (i + j));
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest::check;

    #[test]
    fn bits_extract() {
        assert_eq!(act_bit(0b1010, 1), 1);
        assert_eq!(act_bit(0b1010, 0), 0);
        // -1 in 8-bit two's complement is 0xFF
        for i in 0..8 {
            assert_eq!(weight_bit(-1, i, 8), 1);
        }
        assert_eq!(weight_bit(-128, 7, 8), 1);
        assert_eq!(weight_bit(-128, 6, 8), 0);
    }

    #[test]
    fn quantize_rounding() {
        assert_eq!(quantize_clamped(2.5, 1.0, 0, 255), 3); // half-up
        assert_eq!(quantize_clamped(-0.4, 1.0, 0, 255), 0);
        assert_eq!(quantize_clamped(300.0, 1.0, 0, 255), 255);
        assert_eq!(quantize_act(-5.0, 1.0), 0);
    }

    #[test]
    fn eq1_recomposition_matches_exact_dot() {
        let sp = MacroSpec::default();
        check("eq1 recomposition", 100, |g| {
            let n = g.usize_in(1, 200);
            let a = g.acts(n);
            let w = g.weights(n);
            let sp = sp;
            let d = order_partials_naive(&a, &w, &sp);
            assert_eq!(recompose(&d, &sp), exact_dot(&a, &w) as i64);
        });
    }

    #[test]
    fn packed_matches_naive() {
        let sp = MacroSpec::default();
        check("packed popcount == naive", 100, |g| {
            let n = g.usize_in(1, 200);
            let a = g.acts(n);
            let w = g.weights(n);
            let pa = PackedBits::pack(&a, sp.a_bits, false);
            let pw = PackedBits::pack(&w, sp.w_bits, true);
            let d = order_partials_naive(&a, &w, &sp);
            for i in 0..sp.w_bits {
                for j in 0..sp.a_bits {
                    assert_eq!(pw.and_popcount(i, &pa, j), d[i][j], "i={i} j={j}");
                }
            }
        });
    }

    #[test]
    fn packed_shapes() {
        let p = PackedBits::pack(&[1; 144], 8, false);
        assert_eq!(p.n_words, 3);
        assert_eq!(p.plane(0).iter().map(|w| w.count_ones()).sum::<u32>(), 144);
        assert_eq!(p.plane(1).iter().map(|w| w.count_ones()).sum::<u32>(), 0);
    }

    #[test]
    fn plane_sign_convention() {
        assert_eq!(plane_sign(7, 8), -1);
        assert_eq!(plane_sign(0, 8), 1);
        assert_eq!(plane_sign(3, 4), -1);
    }
}
