//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python -m compile.aot` and executes them on the CPU PJRT client.
//!
//! The real implementation (`pjrt`, feature `pjrt`) is the only code
//! touching the `xla` crate, which exists solely in the offline mirror.
//! Default builds get an API-compatible `stub` whose `Runtime::load`
//! returns a clear error, so the rest of the stack (tests, examples,
//! the coordinator) compiles and runs on the native engine without the
//! bindings.  Both variants implement `sched::GemmEngine` and draw their
//! weight tiles from the shared `sched::plan::PlanCache`.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtGemm, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtGemm, Runtime};

#[cfg(test)]
mod tests {
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_errors_clearly() {
        let err = super::Runtime::load(std::path::Path::new("nowhere"), false).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    // Real-runtime tests require built artifacts and the PJRT plugin;
    // they live in rust/tests/artifact_parity.rs so `cargo test --lib`
    // stays hermetic.
}
