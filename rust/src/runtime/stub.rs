//! API-compatible stub used when the crate is built **without** the
//! `pjrt` feature (the `xla` bindings are only available from the
//! offline mirror).  Everything compiles and links; constructing a
//! [`Runtime`] fails with a clear error, so `PjrtGemm` can never be
//! driven — callers fall back to the native `sched::MacroGemm` engine.

use crate::config::CimMode;
use crate::energy::EnergyParams;
use crate::macrosim::ose::Ose;
use crate::sched::plan::{PlanCache, PlanCacheStats};
use crate::sched::{GemmEngine, GemmResult};
use crate::spec::MacroSpec;
use anyhow::{bail, Result};
use std::path::Path;
use std::sync::Arc;

const UNAVAILABLE: &str = "PJRT runtime unavailable: built without the `pjrt` feature (the \
     `xla` crate is not in the offline mirror); use the native engine instead";

/// Stub of the PJRT artifact runtime — [`Runtime::load`] always errors.
pub struct Runtime {
    pub model_batch: usize,
}

impl Runtime {
    pub fn load(_artifacts_dir: &Path, _with_model: bool) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform(&self) -> String {
        "pjrt-unavailable".into()
    }

    pub fn se_tile(&self, _a: &[i32], _w: &[i32]) -> Result<Vec<i32>> {
        bail!(UNAVAILABLE)
    }

    pub fn hybrid_tile(
        &self,
        _a: &[i32],
        _w: &[i32],
        _b: &[i32],
        _noise: &[f32],
    ) -> Result<Vec<i32>> {
        bail!(UNAVAILABLE)
    }

    pub fn model_forward(&self, _x: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    pub fn model_forward_all(
        &self,
        _images_u8: &[u8],
        _n: usize,
        _classes: usize,
    ) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }
}

/// Stub of the PJRT GEMM engine; mirrors the real field/method surface so
/// downstream code (tests, examples) compiles unchanged.
pub struct PjrtGemm<'r> {
    pub rt: &'r Runtime,
    pub mode: CimMode,
    pub spec: MacroSpec,
    pub fixed_b: i32,
    pub ose: Ose,
    pub noise_seed: u64,
    pub energy: EnergyParams,
    plans: Arc<PlanCache>,
}

impl<'r> PjrtGemm<'r> {
    pub fn new(rt: &'r Runtime, mode: CimMode, thresholds: Vec<i32>) -> Result<Self> {
        Ok(Self {
            rt,
            mode,
            spec: MacroSpec::default(),
            fixed_b: 8,
            ose: Ose::with_default_candidates(thresholds)?,
            noise_seed: 0xC1A0_2024,
            energy: EnergyParams::default(),
            plans: Arc::new(PlanCache::new()),
        })
    }

    pub fn with_plan_cache(mut self, plans: Arc<PlanCache>) -> Self {
        self.plans = plans;
        self
    }

    pub fn plan_stats(&self) -> PlanCacheStats {
        self.plans.stats()
    }
}

impl<'r> GemmEngine for PjrtGemm<'r> {
    fn name(&self) -> &str {
        "pjrt-unavailable"
    }

    fn prepare(&mut self, w: &[i32], n: usize, k: usize, layer_idx: u64) -> Result<()> {
        self.plans.get_or_build(layer_idx, w, n, k, self.spec).map(|_| ())
    }

    fn gemm(
        &mut self,
        _a: &[i32],
        _m: usize,
        _k: usize,
        _w: &[i32],
        _n: usize,
        _layer_idx: u64,
    ) -> Result<GemmResult> {
        bail!(UNAVAILABLE)
    }
}
