//! Real PJRT runtime over the `xla` bindings (feature `pjrt`).
//!
//! Loads the AOT-compiled HLO-text artifacts produced by
//! `python -m compile.aot` and executes them on the CPU PJRT client.
//! This is the only module touching the `xla` crate.  The interchange
//! format is HLO *text* (jax >= 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects in proto form; the text parser reassigns
//! ids — see /opt/xla-example/README.md).  All artifacts are lowered with
//! `return_tuple=True`, so results unwrap with `to_tuple1`.

use crate::sched::plan::PlanCache;
use crate::sched::{GemmEngine, GemmResult};
use crate::spec::{MacroSpec, TILE_M};
use crate::util::prng::{unit_noise_seed, SplitMix64};
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// A compiled artifact cache over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    se_tile: xla::PjRtLoadedExecutable,
    hybrid_tile: xla::PjRtLoadedExecutable,
    model: Option<xla::PjRtLoadedExecutable>,
    pub model_batch: usize,
    sp: MacroSpec,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
}

impl Runtime {
    /// Load and compile the tile artifacts (and the float model when
    /// `with_model`) from the artifacts directory.
    pub fn load(artifacts_dir: &Path, with_model: bool) -> Result<Self> {
        let sp = MacroSpec::default();
        sp.validate_against_artifacts(artifacts_dir)
            .context("spec.json mismatch — rebuild artifacts")?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        let se_tile = compile(&client, &artifacts_dir.join("se_tile.hlo.txt"))?;
        let hybrid_tile = compile(&client, &artifacts_dir.join("hybrid_tile.hlo.txt"))?;
        let model = if with_model {
            Some(compile(&client, &artifacts_dir.join("model.hlo.txt"))?)
        } else {
            None
        };
        log::info!(
            "runtime: compiled artifacts on {} ({} devices)",
            client.platform_name(),
            client.device_count()
        );
        Ok(Self { client, se_tile, hybrid_tile, model, model_batch: 128, sp })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Saliency-evaluation tile: `a [TILE_M, cols]`, `w [hmus, cols]`
    /// -> `S [TILE_M]`.
    pub fn se_tile(&self, a: &[i32], w: &[i32]) -> Result<Vec<i32>> {
        let sp = &self.sp;
        ensure!(a.len() == TILE_M * sp.cols && w.len() == sp.hmus * sp.cols);
        let a_l = xla::Literal::vec1(a).reshape(&[TILE_M as i64, sp.cols as i64])?;
        let w_l = xla::Literal::vec1(w).reshape(&[sp.hmus as i64, sp.cols as i64])?;
        let out = self.se_tile.execute::<xla::Literal>(&[a_l, w_l])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }

    /// Computing-mode hybrid tile: `a [TILE_M, cols]`, `w [hmus, cols]`,
    /// `b [TILE_M]`, `noise [TILE_M, hmus, w_bits]` -> `[TILE_M, hmus]`.
    pub fn hybrid_tile(&self, a: &[i32], w: &[i32], b: &[i32], noise: &[f32]) -> Result<Vec<i32>> {
        let sp = &self.sp;
        ensure!(a.len() == TILE_M * sp.cols, "a len {}", a.len());
        ensure!(b.len() == TILE_M);
        ensure!(noise.len() == TILE_M * sp.hmus * sp.w_bits);
        let a_l = xla::Literal::vec1(a).reshape(&[TILE_M as i64, sp.cols as i64])?;
        let w_l = xla::Literal::vec1(w).reshape(&[sp.hmus as i64, sp.cols as i64])?;
        let b_l = xla::Literal::vec1(b);
        let n_l = xla::Literal::vec1(noise).reshape(&[
            TILE_M as i64,
            sp.hmus as i64,
            sp.w_bits as i64,
        ])?;
        let out = self.hybrid_tile.execute::<xla::Literal>(&[a_l, w_l, b_l, n_l])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }

    /// Float golden model: `x [batch, 32, 32, 3]` -> logits `[batch, 10]`.
    pub fn model_forward(&self, x: &[f32]) -> Result<Vec<f32>> {
        let exe = self.model.as_ref().context("runtime loaded without the model artifact")?;
        let b = self.model_batch;
        ensure!(x.len() == b * 32 * 32 * 3, "model expects a full batch of {b}");
        let x_l = xla::Literal::vec1(x).reshape(&[b as i64, 32, 32, 3])?;
        let out = exe.execute::<xla::Literal>(&[x_l])?[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Float golden model over an arbitrary number of images (pads the
    /// final batch).
    pub fn model_forward_all(&self, images_u8: &[u8], n: usize, classes: usize) -> Result<Vec<f32>> {
        let b = self.model_batch;
        let img = 32 * 32 * 3;
        let mut logits = vec![0.0f32; n * classes];
        let mut start = 0usize;
        while start < n {
            let take = (n - start).min(b);
            let mut xbuf = vec![0.0f32; b * img];
            for (dst, &src) in
                xbuf.iter_mut().zip(&images_u8[start * img..(start + take) * img])
            {
                *dst = src as f32 / 255.0;
            }
            let out = self.model_forward(&xbuf)?;
            logits[start * classes..(start + take) * classes]
                .copy_from_slice(&out[..take * classes]);
            start += take;
        }
        Ok(logits)
    }
}

/// [`GemmEngine`] implementation over the PJRT tile artifacts — the
/// production hot path (Python never runs; the tiles were AOT-lowered
/// from the L1 Pallas kernels).
///
/// Follows the same tiling and noise-stream convention as
/// `sched::MacroGemm`, so for a given seed the two engines produce
/// bit-identical outputs (asserted in `rust/tests/artifact_parity.rs`).
/// Weight tiles come from the shared [`PlanCache`]: a layer's `[hmus,
/// cols]` tile buffers are gathered once and re-dispatched verbatim on
/// every call.
pub struct PjrtGemm<'r> {
    pub rt: &'r Runtime,
    pub mode: crate::config::CimMode,
    pub spec: MacroSpec,
    pub fixed_b: i32,
    pub ose: crate::macrosim::ose::Ose,
    pub noise_seed: u64,
    pub energy: crate::energy::EnergyParams,
    plans: Arc<PlanCache>,
}

impl<'r> PjrtGemm<'r> {
    pub fn new(rt: &'r Runtime, mode: crate::config::CimMode, thresholds: Vec<i32>) -> Result<Self> {
        Ok(Self {
            rt,
            mode,
            spec: MacroSpec::default(),
            fixed_b: 8,
            ose: crate::macrosim::ose::Ose::with_default_candidates(thresholds)?,
            noise_seed: 0xC1A0_2024,
            energy: crate::energy::EnergyParams::default(),
            plans: Arc::new(PlanCache::new()),
        })
    }

    /// Attach an externally shared plan cache (e.g. one shared with the
    /// native engine — plans are engine-agnostic).
    pub fn with_plan_cache(mut self, plans: Arc<PlanCache>) -> Self {
        self.plans = plans;
        self
    }

    /// Cache activity snapshot.
    pub fn plan_stats(&self) -> crate::sched::plan::PlanCacheStats {
        self.plans.stats()
    }
}

impl<'r> GemmEngine for PjrtGemm<'r> {
    fn name(&self) -> &str {
        "pjrt-artifacts"
    }

    fn prepare(&mut self, w: &[i32], n: usize, k: usize, layer_idx: u64) -> Result<()> {
        self.plans.get_or_build(layer_idx, w, n, k, self.spec).map(|_| ())
    }

    fn gemm(
        &mut self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
        layer_idx: u64,
    ) -> Result<GemmResult> {
        use crate::config::CimMode;
        use crate::energy::EnergyAccount;
        use crate::sched::pad_cols;

        let sp = self.spec;
        ensure!(
            matches!(self.mode, CimMode::Dcim | CimMode::Hcim | CimMode::Osa),
            "PjrtGemm supports dcim|hcim|osa; {} runs through the native engine",
            self.mode.name()
        );
        let plan = self.plans.get_or_build(layer_idx, w, n, k, sp)?;
        let (kt, nt, k_pad, n_pad) = (plan.kt, plan.nt, plan.k_pad, plan.n_pad);
        let a_p = pad_cols(a, m, k, k_pad);
        let mt = m.div_ceil(TILE_M); // sample-axis tiling to the artifact shape

        let mut out = vec![0i32; m * n_pad];
        let mut account = EnergyAccount::default();
        let mut b_hist = [0u64; 16];
        let mut bda = vec![0i32; m * nt];

        // Gather the K-tile activation buffers once per sample-tile:
        // [TILE_M, cols] per (mi, ki).
        let tile_a = |mi: usize, ki: usize| -> Vec<i32> {
            let mut buf = vec![0i32; TILE_M * sp.cols];
            for s in 0..TILE_M {
                let src = mi * TILE_M + s;
                if src >= m {
                    break;
                }
                buf[s * sp.cols..(s + 1) * sp.cols].copy_from_slice(
                    &a_p[src * k_pad + ki * sp.cols..src * k_pad + (ki + 1) * sp.cols],
                );
            }
            buf
        };

        for ni in 0..nt {
            // boundaries per sample
            let mut boundaries = vec![crate::spec::B_DCIM; m];
            match self.mode {
                CimMode::Dcim => {}
                CimMode::Hcim => boundaries.iter_mut().for_each(|b| *b = self.fixed_b),
                CimMode::Osa => {
                    let mut s_acc = vec![0i64; m];
                    for mi in 0..mt {
                        for ki in 0..kt {
                            let abuf = tile_a(mi, ki);
                            let s_out = self.rt.se_tile(&abuf, plan.unit(ni, ki).weights())?;
                            for s in 0..TILE_M {
                                let idx = mi * TILE_M + s;
                                if idx < m {
                                    s_acc[idx] += s_out[s] as i64;
                                }
                            }
                        }
                    }
                    // N/Q normalization by the layer's true K depth
                    let s_norm: Vec<i32> = s_acc
                        .iter()
                        .map(|&s| crate::spec::normalize_saliency(s, k, sp.cols))
                        .collect();
                    boundaries = self.ose.select_batch(&s_norm);
                }
                _ => unreachable!(),
            }

            // per-unit noise streams (DESIGN.md §6): row `s` of N-tile
            // `ni` draws from its own `(seed, layer, row, tile)` stream,
            // advanced K-tile-major — the same convention as the native
            // engine, so the two stay bit-comparable.  DCIM / noiseless
            // runs never draw, so don't seed streams for them either.
            let draw_noise = sp.sigma_code != 0.0 && self.mode != CimMode::Dcim;
            let mut streams: Vec<SplitMix64> = if draw_noise {
                (0..m)
                    .map(|s| {
                        SplitMix64::new(unit_noise_seed(
                            self.noise_seed,
                            layer_idx,
                            s as u64,
                            ni as u64,
                        ))
                    })
                    .collect()
            } else {
                Vec::new()
            };
            for ki in 0..kt {
                let wt = plan.unit(ni, ki).weights();
                let per_sample = sp.hmus * sp.w_bits;
                let mut noise_all = vec![0.0f32; m * per_sample];
                if draw_noise {
                    for (s, stream) in streams.iter_mut().enumerate() {
                        let buf = stream.normals_f32(per_sample, sp.sigma_code);
                        noise_all[s * per_sample..(s + 1) * per_sample].copy_from_slice(&buf);
                    }
                }
                for mi in 0..mt {
                    let abuf = tile_a(mi, ki);
                    let mut bbuf = vec![0i32; TILE_M];
                    let mut nbuf = vec![0.0f32; TILE_M * per_sample];
                    for s in 0..TILE_M {
                        let idx = mi * TILE_M + s;
                        if idx < m {
                            bbuf[s] = boundaries[idx];
                            nbuf[s * per_sample..(s + 1) * per_sample].copy_from_slice(
                                &noise_all[idx * per_sample..(idx + 1) * per_sample],
                            );
                        } else {
                            bbuf[s] = 15; // pad rows: discard-everything boundary
                        }
                    }
                    let vals = self.rt.hybrid_tile(&abuf, wt, &bbuf, &nbuf)?;
                    for s in 0..TILE_M {
                        let idx = mi * TILE_M + s;
                        if idx >= m {
                            break;
                        }
                        for h in 0..sp.hmus {
                            out[idx * n_pad + ni * sp.hmus + h] += vals[s * sp.hmus + h];
                        }
                    }
                }
                // energy accounting (same model as the native engine)
                for &b in boundaries.iter() {
                    let with_se = self.mode == CimMode::Osa;
                    let c = plan.counts(b, with_se);
                    account.record(&self.energy.op_energy(&c, with_se, &sp), &c);
                }
            }

            for s in 0..m {
                bda[s * nt + ni] = boundaries[s];
                let b = boundaries[s];
                if (0..16).contains(&b) {
                    b_hist[b as usize] += kt as u64;
                }
            }
        }

        let mut final_out = vec![0i32; m * n];
        for s in 0..m {
            final_out[s * n..(s + 1) * n].copy_from_slice(&out[s * n_pad..s * n_pad + n]);
        }
        Ok(GemmResult { out: final_out, m, n, account, b_hist, bda, n_tiles: nt })
    }
}
