//! Plan/execute parity: the `LayerPlan`-cached engine must be
//! bit-identical to a plan-free reference that re-packs every weight
//! tile per call (the seed semantics), for every `CimMode`; and a layer
//! must be packed exactly once per process (cache-reuse + clone-sharing
//! tests).  Needs no artifacts.

use osa_hcim::config::CimMode;
use osa_hcim::macrosim::ose::{Ose, SaliencyAccumulator};
use osa_hcim::macrosim::MacroUnit;
use osa_hcim::sched::{pad_cols, pad_matrix, GemmEngine, MacroGemm};
use osa_hcim::spec::MacroSpec;
use osa_hcim::util::prng::{unit_noise_seed, SplitMix64};

const MODES: [CimMode; 6] =
    [CimMode::Dcim, CimMode::Hcim, CimMode::Osa, CimMode::Acim, CimMode::Pg, CimMode::Drq];

/// Plan-free reference engine: packs weights from scratch on every call,
/// runs strictly sequentially, and mirrors the per-unit noise-stream
/// convention (DESIGN.md §6: one SplitMix64 stream per `(layer, row,
/// N-tile)`, advanced K-tile-major, `hmus*w_bits` normals per K-tile).
struct Reference {
    mode: CimMode,
    sp: MacroSpec,
    fixed_b: i32,
    ose: Ose,
    noise_seed: u64,
    pg_delta: i32,
    drq_thresh: i32,
}

impl Reference {
    /// Mirror of `MacroGemm::with_mode` defaults.
    fn for_mode(mode: CimMode) -> Self {
        Self {
            mode,
            sp: MacroSpec::default(),
            fixed_b: 8,
            ose: Ose::with_default_candidates(vec![0, 0, 32, 94, 1024]).unwrap(),
            noise_seed: 0xC1A0_2024,
            pg_delta: 1 << 13,
            drq_thresh: 48,
        }
    }

    /// Returns (out `[m, n]`, bda `[m, nt]`).
    fn gemm(
        &self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
        layer_idx: u64,
    ) -> (Vec<i32>, Vec<i32>) {
        if matches!(self.mode, CimMode::Pg | CimMode::Drq) {
            return self.gemm_dual(a, m, k, w, n);
        }
        let sp = self.sp;
        let kt = k.div_ceil(sp.cols).max(1);
        let nt = n.div_ceil(sp.hmus).max(1);
        let k_pad = kt * sp.cols;
        let n_pad = nt * sp.hmus;
        let a_p = pad_cols(a, m, k, k_pad);
        let w_p = pad_matrix(w, n, k, n_pad, k_pad);
        let mut out = vec![0i32; m * n_pad];
        let mut bda = vec![0i32; m * nt];
        for ni in 0..nt {
            // pack this N-tile's macros from scratch (no plan, no cache)
            let units: Vec<MacroUnit> = (0..kt)
                .map(|ki| {
                    let mut wt = Vec::with_capacity(sp.hmus * sp.cols);
                    for h in 0..sp.hmus {
                        let row = (ni * sp.hmus + h) * k_pad + ki * sp.cols;
                        wt.extend_from_slice(&w_p[row..row + sp.cols]);
                    }
                    MacroUnit::new(&wt, sp).unwrap()
                })
                .collect();
            let boundaries: Vec<i32> = match self.mode {
                CimMode::Dcim => vec![osa_hcim::spec::B_DCIM; m],
                CimMode::Hcim => vec![self.fixed_b; m],
                CimMode::Acim => vec![-1; m],
                CimMode::Osa => (0..m)
                    .map(|s| {
                        let mut acc = SaliencyAccumulator::default();
                        for (ki, unit) in units.iter().enumerate() {
                            let tile = &a_p
                                [s * k_pad + ki * sp.cols..s * k_pad + (ki + 1) * sp.cols];
                            acc.add(unit.saliency(&unit.pack_acts(tile)));
                        }
                        let s_norm = osa_hcim::spec::normalize_saliency(
                            acc.value() as i64,
                            k,
                            sp.cols,
                        );
                        self.ose.select(s_norm)
                    })
                    .collect(),
                CimMode::Pg | CimMode::Drq => unreachable!(),
            };
            let per_tile = if self.mode == CimMode::Acim {
                sp.hmus * sp.w_bits * sp.a_bits.div_ceil(sp.analog_band as usize)
            } else {
                sp.hmus * sp.w_bits
            };
            for s in 0..m {
                // one stream per (layer, row, N-tile), advanced K-tile-major
                let mut stream = SplitMix64::new(unit_noise_seed(
                    self.noise_seed,
                    layer_idx,
                    s as u64,
                    ni as u64,
                ));
                for (ki, unit) in units.iter().enumerate() {
                    let noise = if self.mode == CimMode::Dcim || sp.sigma_code == 0.0 {
                        vec![0.0f32; per_tile]
                    } else {
                        stream.normals_f32(per_tile, sp.sigma_code)
                    };
                    let tile =
                        &a_p[s * k_pad + ki * sp.cols..s * k_pad + (ki + 1) * sp.cols];
                    let vals = match self.mode {
                        CimMode::Dcim => unit.exact(tile),
                        CimMode::Acim => unit.compute_acim(&unit.pack_acts(tile), &noise),
                        CimMode::Osa | CimMode::Hcim => {
                            unit.compute_hybrid(&unit.pack_acts(tile), boundaries[s], &noise)
                        }
                        CimMode::Pg | CimMode::Drq => unreachable!(),
                    };
                    for h in 0..sp.hmus {
                        out[s * n_pad + ni * sp.hmus + h] += vals[h];
                    }
                }
            }
            for s in 0..m {
                bda[s * nt + ni] = boundaries[s];
            }
        }
        let mut final_out = vec![0i32; m * n];
        for s in 0..m {
            final_out[s * n..(s + 1) * n].copy_from_slice(&out[s * n_pad..s * n_pad + n]);
        }
        (final_out, bda)
    }

    /// Seed-style dual-precision path: flat K, raw weight indexing.
    fn gemm_dual(&self, a: &[i32], m: usize, k: usize, w: &[i32], n: usize) -> (Vec<i32>, Vec<i32>) {
        let sp = self.sp;
        let nt = n.div_ceil(sp.hmus).max(1);
        let mut out = vec![0i32; m * n];
        let mut bda = vec![0i32; m * nt];
        for s in 0..m {
            let row = &a[s * k..(s + 1) * k];
            let drq_full = if self.mode == CimMode::Drq {
                let mean: i64 = row.iter().map(|&x| x as i64).sum::<i64>() / k as i64;
                mean >= self.drq_thresh as i64
            } else {
                false
            };
            for ni in 0..nt {
                let mut full = self.mode == CimMode::Drq && drq_full;
                let c_lo = ni * sp.hmus;
                let c_hi = ((ni + 1) * sp.hmus).min(n);
                let hi_vals: Vec<i32> = (c_lo..c_hi)
                    .map(|c| {
                        let wr = &w[c * k..(c + 1) * k];
                        row.iter().zip(wr).map(|(&x, &y)| (x & !0xF) * y).sum()
                    })
                    .collect();
                if self.mode == CimMode::Pg {
                    full = hi_vals.iter().any(|v| v.abs() >= self.pg_delta);
                }
                for (ci, c) in (c_lo..c_hi).enumerate() {
                    out[s * n + c] = if full {
                        let wr = &w[c * k..(c + 1) * k];
                        row.iter().zip(wr).map(|(&x, &y)| x * y).sum()
                    } else {
                        hi_vals[ci]
                    };
                }
                bda[s * nt + ni] = full as i32;
            }
        }
        (out, bda)
    }
}

fn rand_inputs(seed: u64, m: usize, k: usize, n: usize) -> (Vec<i32>, Vec<i32>) {
    let mut g = SplitMix64::new(seed);
    let a = (0..m * k).map(|_| g.next_range_i32(0, 256)).collect();
    let w = (0..n * k).map(|_| g.next_range_i32(-128, 128)).collect();
    (a, w)
}

#[test]
fn plan_outputs_bit_identical_to_plan_free_reference() {
    let mut shapes = SplitMix64::new(0xBEEF);
    for mode in MODES {
        for round in 0..3u64 {
            let m = shapes.next_below(5) + 1;
            let k = shapes.next_below(400) + 1;
            let n = shapes.next_below(24) + 1;
            let (a, w) = rand_inputs(round * 7 + 1, m, k, n);
            let mut engine = MacroGemm::with_mode(mode);
            let r = engine.gemm(&a, m, k, &w, n, round).unwrap();
            let reference = Reference::for_mode(mode);
            let (out, bda) = reference.gemm(&a, m, k, &w, n, round);
            assert_eq!(r.out, out, "mode {} m={m} k={k} n={n} round={round}", mode.name());
            assert_eq!(r.bda, bda, "mode {} boundaries m={m} k={k} n={n}", mode.name());
        }
    }
}

#[test]
fn second_call_reuses_cached_plan_no_repack() {
    let (m, k, n) = (8usize, 300usize, 20usize);
    let (a1, w) = rand_inputs(1, m, k, n);
    let (a2, _) = rand_inputs(2, m, k, n);
    let mut gemm = MacroGemm::with_mode(CimMode::Osa);
    gemm.gemm(&a1, m, k, &w, n, 4).unwrap();
    let s1 = gemm.plan_stats();
    assert_eq!((s1.hits, s1.misses, s1.layers), (0, 1, 1));
    // different activations, same layer: plan must be reused, not rebuilt
    gemm.gemm(&a2, m, k, &w, n, 4).unwrap();
    let s2 = gemm.plan_stats();
    assert_eq!((s2.hits, s2.misses), (1, 1), "second call re-packed the layer");
    // identical inputs through the cached plan stay bit-identical
    let r1 = gemm.gemm(&a1, m, k, &w, n, 4).unwrap();
    let r2 = gemm.gemm(&a1, m, k, &w, n, 4).unwrap();
    assert_eq!(r1.out, r2.out);
    assert_eq!(r1.b_hist, r2.b_hist);
    // a distinct layer index builds a distinct plan
    gemm.gemm(&a1, m, k, &w, n, 5).unwrap();
    assert_eq!(gemm.plan_stats().misses, 2);
}

#[test]
fn clones_share_one_cache_packing_once_per_process() {
    let (m, k, n) = (4usize, 144usize, 8usize);
    let (a, w) = rand_inputs(3, m, k, n);
    let gemm = MacroGemm::with_mode(CimMode::Hcim);
    let mut c1 = gemm.clone();
    let mut c2 = gemm.clone();
    let r1 = c1.gemm(&a, m, k, &w, n, 0).unwrap();
    let r2 = c2.gemm(&a, m, k, &w, n, 0).unwrap();
    assert_eq!(r1.out, r2.out, "clones must agree bit-exactly");
    let s = gemm.plan_stats();
    assert_eq!(s.misses, 1, "weights packed more than once across clones");
    assert_eq!(s.hits, 1);
}

#[test]
fn prepare_prebuilds_and_gemm_hits() {
    let (m, k, n) = (4usize, 144usize, 8usize);
    let (a, w) = rand_inputs(4, m, k, n);
    let mut gemm = MacroGemm::with_mode(CimMode::Dcim);
    gemm.prepare(&w, n, k, 3).unwrap();
    assert_eq!(gemm.plan_stats().misses, 1);
    gemm.gemm(&a, m, k, &w, n, 3).unwrap();
    let s = gemm.plan_stats();
    assert_eq!((s.hits, s.misses), (1, 1), "gemm after prepare must hit the cache");
}

#[test]
fn dimension_drift_under_cached_index_is_rejected() {
    let (m, k, n) = (4usize, 100usize, 8usize);
    let (a, w) = rand_inputs(5, m, k, n);
    let mut gemm = MacroGemm::with_mode(CimMode::Dcim);
    gemm.gemm(&a, m, k, &w, n, 0).unwrap();
    let (a2, w2) = rand_inputs(6, m, 50, n);
    assert!(
        gemm.gemm(&a2, m, 50, &w2, n, 0).is_err(),
        "shape change under a cached layer index must fail loudly"
    );
}
