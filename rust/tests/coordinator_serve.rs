//! Coordinator integration: live server over the native engine.
//!
//! Accuracy tests require `make artifacts` (skip when absent); the
//! drain / error-response / plan-cache tests run over
//! `QGraph::synthetic()` and need nothing on disk.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::coordinator::Server;
use osa_hcim::engine::{
    Backend, BackendCtx, BackendKnobs, BackendRegistry, BackendSpec, Capabilities, Engine,
    InferOptions, InferRequest,
};
use osa_hcim::nn::data::Dataset;
use osa_hcim::nn::QGraph;
use osa_hcim::sched::GemmResult;
use osa_hcim::serve::{SubmitError, Tier};
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts() -> Option<PathBuf> {
    let dir = osa_hcim::spec::default_artifacts_dir();
    dir.join("spec.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn setup(cfg: &SystemConfig) -> (Server, Dataset) {
    let ds = Dataset::load(&cfg.artifacts_dir).unwrap();
    let graph = Arc::new(QGraph::load(&cfg.artifacts_dir).unwrap());
    (Server::start(cfg, graph).unwrap(), ds)
}

#[test]
fn serves_requests_and_answers_all() {
    let _dir = require_artifacts!();
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 2;
    cfg.max_batch = 8;
    let (server, ds) = setup(&cfg);
    let n = 24usize.min(ds.test_n());
    let mut pending = Vec::new();
    for i in 0..n {
        let (img, _) = ds.test_batch(i, 1);
        pending.push((i, server.submit(img.to_vec()).unwrap()));
    }
    let mut correct = 0;
    let mut ids = std::collections::HashSet::new();
    for (i, rx) in pending {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "request {i} errored: {:?}", resp.error);
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.batch_size >= 1);
        assert!(ids.insert(resp.id), "duplicate response id");
        if resp.pred as i32 == ds.test_y[i] {
            correct += 1;
        }
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, n as u64);
    assert!(metrics.batches >= 1);
    assert!(correct as f64 / n as f64 > 0.85, "serving path broke accuracy");
    assert!(metrics.p95_latency_us() >= metrics.p50_latency_us());
    assert!(metrics.tops_per_watt(&cfg.spec) > 1.0);
}

#[test]
fn batcher_coalesces_under_load() {
    let _dir = require_artifacts!();
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    cfg.max_batch = 16;
    cfg.batch_timeout_us = 50_000; // generous window so the burst coalesces
    let (server, ds) = setup(&cfg);
    let n = 32;
    let mut pending = Vec::new();
    for i in 0..n {
        let (img, _) = ds.test_batch(i, 1);
        pending.push(server.submit(img.to_vec()).unwrap());
    }
    for rx in pending {
        rx.recv().expect("response");
    }
    let metrics = server.shutdown();
    assert!(
        metrics.mean_batch() > 1.5,
        "burst of {n} produced mean batch {:.2}",
        metrics.mean_batch()
    );
}

#[test]
fn shutdown_is_clean_and_rejects_after() {
    let _dir = require_artifacts!();
    let cfg = SystemConfig::default();
    let (server, ds) = setup(&cfg);
    let (img, _) = ds.test_batch(0, 1);
    let rx = server.submit(img.to_vec()).unwrap();
    rx.recv().expect("response before shutdown");
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 1);
}

// ---- artifact-free tests over the synthetic graph ----------------------

fn synth_server(cfg: &SystemConfig) -> Server {
    Server::start(cfg, Arc::new(QGraph::synthetic())).unwrap()
}

fn synth_image(seed: u64) -> Vec<u8> {
    let mut g = osa_hcim::util::prng::SplitMix64::new(seed);
    (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
}

#[test]
fn drain_on_shutdown_answers_every_request() {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 2;
    cfg.max_batch = 4;
    cfg.batch_timeout_us = 1_000;
    let server = synth_server(&cfg);
    let n = 10usize;
    let mut pending = Vec::new();
    for i in 0..n {
        pending.push(server.submit(synth_image(i as u64)).unwrap());
    }
    // shutdown must flush everything already submitted before joining
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, n as u64, "shutdown dropped requests");
    assert_eq!(metrics.errors, 0);
    assert!(metrics.batches >= 1);
    for rx in pending {
        let resp = rx.recv().expect("every submitted request must be answered");
        assert!(resp.error.is_none());
        assert_eq!(resp.logits.len(), 10);
    }
}

/// A registry entry whose every GEMM fails — drives the worker's
/// answer-with-error path deterministically through the public
/// extension point (a custom `BackendRegistry`).
struct FailingBackend;

impl Backend for FailingBackend {
    fn gemm(
        &mut self,
        _a: &[i32],
        _m: usize,
        _k: usize,
        _w: &[i32],
        _n: usize,
        _layer_idx: u64,
    ) -> anyhow::Result<GemmResult> {
        anyhow::bail!("injected gemm failure")
    }

    fn prepare(&mut self, _w: &[i32], _n: usize, _k: usize, _layer_idx: u64) -> anyhow::Result<()> {
        Ok(())
    }

    fn name(&self) -> &str {
        "failing"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            available: true,
            mode: CimMode::Dcim,
            macros: 1,
            residency_bytes: 0,
            programmable_thresholds: false,
            hybrid_boundary: false,
            pooling: false,
            cost_model: "compact",
            memory_levels: 0,
            description: "test backend that always fails",
        }
    }

    fn apply(&mut self, _knobs: &BackendKnobs) -> anyhow::Result<()> {
        Ok(())
    }

    fn thresholds(&self) -> Option<Vec<i32>> {
        None
    }

    fn clone_backend(&self) -> anyhow::Result<Box<dyn Backend>> {
        Ok(Box::new(FailingBackend))
    }
}

fn failing_factory(_ctx: &BackendCtx) -> anyhow::Result<Box<dyn Backend>> {
    Ok(Box::new(FailingBackend))
}

#[test]
fn forward_error_answers_with_error_response() {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    cfg.max_batch = 2;
    cfg.batch_timeout_us = 1_000;
    let mut registry = BackendRegistry::builtin();
    registry.register(BackendSpec {
        name: "failing",
        description: "test backend that always fails",
        available: true,
        factory: failing_factory,
    });
    let engine = Engine::builder()
        .config(cfg.clone())
        .graph(Arc::new(QGraph::synthetic()))
        .registry(Arc::new(registry))
        .build()
        .unwrap();
    let server = Server::with_engine(Arc::new(engine)).unwrap();
    // a wrong-size image never reaches a worker anymore: typed
    // rejection at submission (the seed behavior dropped the batch and
    // left submitters hanging on a closed channel)
    match server.submit(vec![0u8; 16]) {
        Err(SubmitError::InvalidOption { field, .. }) => assert_eq!(field, "image"),
        other => panic!("expected InvalidOption, got {other:?}"),
    }
    // a forward failure inside the worker answers with an error
    // Response tagged with the failing backend
    let req = InferRequest {
        image: synth_image(0),
        options: InferOptions { backend: Some("failing".into()), ..Default::default() },
    };
    let rx = server.submit_request(req).unwrap();
    let resp = rx.recv().expect("error must be answered, not dropped");
    assert!(resp.error.is_some(), "expected an error response");
    assert!(resp.logits.is_empty());
    assert_eq!(resp.backend, "failing");
    // a well-formed request after the failure is still served
    let rx_ok = server.submit(synth_image(1)).unwrap();
    let ok = rx_ok.recv().expect("server must survive a failed batch");
    assert!(ok.error.is_none());
    let metrics = server.shutdown();
    assert_eq!(metrics.errors, 1);
    assert_eq!(metrics.requests, 1, "failed requests must not count as served");
}

#[test]
fn bounded_queue_surfaces_typed_busy_error() {
    // Seed behavior: `submit` pushed into an unbounded channel, so a
    // slow worker pool meant unbounded memory growth.  Now admission is
    // bounded per tier and overload fails fast with `SubmitError::Busy`.
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    cfg.max_batch = 1;
    cfg.queue_cap = 2;
    cfg.batch_timeout_us = 100;
    let server = synth_server(&cfg);
    let mut accepted = Vec::new();
    let mut busy = 0u64;
    for i in 0..100u64 {
        match server.submit(synth_image(i)) {
            Ok(rx) => accepted.push(rx),
            Err(e @ SubmitError::Busy { .. }) => {
                assert!(e.to_string().contains("busy"), "{e}");
                busy += 1;
            }
            Err(other) => panic!("unexpected submit error: {other}"),
        }
    }
    assert!(busy > 0, "100 rapid submissions against cap=2 never hit backpressure");
    // every *admitted* request is still answered — shedding never drops
    // an accepted channel
    for rx in accepted {
        let resp = rx.recv().expect("admitted request must be answered");
        assert!(resp.error.is_none());
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.rejected, busy, "rejection counter mismatch");
    assert_eq!(metrics.requests + metrics.rejected, 100);
}

#[test]
fn batch_window_is_hard_deadline_from_first_enqueue() {
    // Regression: the seed batcher restarted its timeout window when it
    // *dequeued* the first request, so a steady trickle of arrivals
    // could keep extending the window far past `batch_timeout_us`.  The
    // window now ends at `first_enqueue + window`, hard.
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    cfg.max_batch = 64;
    cfg.queue_cap = 64;
    cfg.batch_timeout_us = 60_000; // batch tier uses the full 60ms window
    let server = synth_server(&cfg);
    let mut pending = Vec::new();
    // 8 arrivals spaced 20ms apart span ~140ms — more than two windows
    for i in 0..8u64 {
        pending.push(server.submit_tier(synth_image(i), Tier::Batch).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    for rx in pending {
        rx.recv().expect("response");
    }
    let metrics = server.shutdown();
    assert!(
        metrics.batches >= 2,
        "a 140ms trickle coalesced into {} batch(es) — the 60ms window was extended",
        metrics.batches
    );
    assert_eq!(metrics.requests, 8);
}

#[test]
fn tiers_are_tracked_separately_in_metrics() {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 2;
    cfg.max_batch = 4;
    cfg.batch_timeout_us = 1_000;
    let server = synth_server(&cfg);
    let mut pending = Vec::new();
    for (i, tier) in [(0u64, Tier::Gold), (1, Tier::Gold), (2, Tier::Batch)] {
        pending.push((tier, server.submit_tier(synth_image(i), tier).unwrap()));
    }
    for (tier, rx) in pending {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none());
        assert_eq!(resp.tier, tier, "response must carry its request's tier");
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.tier(Tier::Gold).requests, 2);
    assert_eq!(metrics.tier(Tier::Batch).requests, 1);
    assert_eq!(metrics.tier(Tier::Silver).requests, 0);
    assert_eq!(metrics.requests, 3);
    assert_eq!(metrics.tier(Tier::Gold).latencies_us.len(), 2);
}

#[test]
fn workers_share_one_plan_cache() {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 4;
    let server = synth_server(&cfg);
    let mut pending = Vec::new();
    for i in 0..8 {
        pending.push(server.submit(synth_image(100 + i)).unwrap());
    }
    for rx in pending {
        rx.recv().expect("response");
    }
    let stats = server.plan_stats();
    // synthetic graph has one conv layer: packed exactly once per
    // process even with 4 workers preplanning concurrently
    assert_eq!(stats.misses, 1, "layer was re-packed: {stats:?}");
    assert!(stats.hits >= 1);
    server.shutdown();
}
