//! Coordinator integration: live server over the native engine
//! (requires `make artifacts`; skips when absent).

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::coordinator::Server;
use osa_hcim::nn::data::Dataset;
use osa_hcim::nn::QGraph;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts() -> Option<PathBuf> {
    let dir = osa_hcim::spec::default_artifacts_dir();
    dir.join("spec.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn setup(cfg: &SystemConfig) -> (Server, Dataset) {
    let ds = Dataset::load(&cfg.artifacts_dir).unwrap();
    let graph = Arc::new(QGraph::load(&cfg.artifacts_dir).unwrap());
    (Server::start(cfg, graph).unwrap(), ds)
}

#[test]
fn serves_requests_and_answers_all() {
    let _dir = require_artifacts!();
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 2;
    cfg.max_batch = 8;
    let (server, ds) = setup(&cfg);
    let n = 24usize.min(ds.test_n());
    let mut pending = Vec::new();
    for i in 0..n {
        let (img, _) = ds.test_batch(i, 1);
        pending.push((i, server.submit(img.to_vec()).unwrap()));
    }
    let mut correct = 0;
    let mut ids = std::collections::HashSet::new();
    for (i, rx) in pending {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.logits.len(), 10);
        assert!(resp.batch_size >= 1);
        assert!(ids.insert(resp.id), "duplicate response id");
        if resp.pred as i32 == ds.test_y[i] {
            correct += 1;
        }
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, n as u64);
    assert!(metrics.batches >= 1);
    assert!(correct as f64 / n as f64 > 0.85, "serving path broke accuracy");
    assert!(metrics.p95_latency_us() >= metrics.p50_latency_us());
    assert!(metrics.tops_per_watt(&cfg.spec) > 1.0);
}

#[test]
fn batcher_coalesces_under_load() {
    let _dir = require_artifacts!();
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    cfg.max_batch = 16;
    cfg.batch_timeout_us = 50_000; // generous window so the burst coalesces
    let (server, ds) = setup(&cfg);
    let n = 32;
    let mut pending = Vec::new();
    for i in 0..n {
        let (img, _) = ds.test_batch(i, 1);
        pending.push(server.submit(img.to_vec()).unwrap());
    }
    for rx in pending {
        rx.recv().expect("response");
    }
    let metrics = server.shutdown();
    assert!(
        metrics.mean_batch() > 1.5,
        "burst of {n} produced mean batch {:.2}",
        metrics.mean_batch()
    );
}

#[test]
fn shutdown_is_clean_and_rejects_after() {
    let _dir = require_artifacts!();
    let cfg = SystemConfig::default();
    let (server, ds) = setup(&cfg);
    let (img, _) = ds.test_batch(0, 1);
    let rx = server.submit(img.to_vec()).unwrap();
    rx.recv().expect("response before shutdown");
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 1);
}
