//! Thread-count determinism: the pooled tile engine must be bit-exact
//! for any worker count — outputs, boundary maps, histograms and even
//! the f64 energy totals (units merge in index order) — across all six
//! `CimMode`s, OSA included.  Plus pool shutdown-under-load behavior.
//! Needs no artifacts.

use osa_hcim::config::CimMode;
use osa_hcim::sched::exec::ExecPool;
use osa_hcim::sched::{GemmEngine, MacroGemm};
use osa_hcim::util::prng::SplitMix64;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

const MODES: [CimMode; 6] =
    [CimMode::Dcim, CimMode::Hcim, CimMode::Osa, CimMode::Acim, CimMode::Pg, CimMode::Drq];

fn rand_inputs(seed: u64, m: usize, k: usize, n: usize) -> (Vec<i32>, Vec<i32>) {
    let mut g = SplitMix64::new(seed);
    let a = (0..m * k).map(|_| g.next_range_i32(0, 256)).collect();
    let w = (0..n * k).map(|_| g.next_range_i32(-128, 128)).collect();
    (a, w)
}

#[test]
fn one_thread_and_many_threads_agree_bit_exactly() {
    // m spans multiple work-unit row chunks; k and n span multiple tiles
    let (m, k, n) = (67usize, 300usize, 20usize);
    let (a, w) = rand_inputs(0xD15C0, m, k, n);
    let pool1 = ExecPool::new(1);
    let pool4 = ExecPool::new(4);
    for mode in MODES {
        let mut e1 = MacroGemm::with_mode(mode).with_pool(pool1.clone());
        let mut e4 = MacroGemm::with_mode(mode).with_pool(pool4.clone());
        let r1 = e1.gemm(&a, m, k, &w, n, 7).unwrap();
        let r4 = e4.gemm(&a, m, k, &w, n, 7).unwrap();
        assert_eq!(r1.out, r4.out, "mode {}: outputs diverge across threads", mode.name());
        assert_eq!(r1.bda, r4.bda, "mode {}: boundary maps diverge", mode.name());
        assert_eq!(r1.b_hist, r4.b_hist, "mode {}: histograms diverge", mode.name());
        assert_eq!(
            r1.account.macro_ops, r4.account.macro_ops,
            "mode {}: op counts diverge",
            mode.name()
        );
        assert_eq!(
            r1.account.cycles, r4.account.cycles,
            "mode {}: cycle counts diverge",
            mode.name()
        );
        // units merge in index order, so even float accumulation is
        // schedule-independent
        assert_eq!(
            r1.account.total_energy_j().to_bits(),
            r4.account.total_energy_j().to_bits(),
            "mode {}: energy totals diverge",
            mode.name()
        );
    }
}

#[test]
fn thread_count_does_not_shift_noise_streams() {
    // the same call on 1, 2 and 3 threads must see the same per-unit
    // noise: identical noisy outputs, not merely identical shapes
    let (m, k, n) = (33usize, 150usize, 10usize);
    let (a, w) = rand_inputs(0xBEE, m, k, n);
    let mut outs = Vec::new();
    for threads in [1usize, 2, 3] {
        let mut e = MacroGemm::with_mode(CimMode::Hcim).with_pool(ExecPool::new(threads));
        outs.push(e.gemm(&a, m, k, &w, n, 3).unwrap().out);
    }
    assert_eq!(outs[0], outs[1]);
    assert_eq!(outs[0], outs[2]);
    // sanity: the noisy path is actually noisy (differs from exact)
    let mut dcim = MacroGemm::with_mode(CimMode::Dcim).with_pool(ExecPool::new(2));
    assert_ne!(outs[0], dcim.gemm(&a, m, k, &w, n, 3).unwrap().out);
}

#[test]
fn shared_pool_serves_concurrent_submitters() {
    // two engines race the same pool: both must come out bit-identical
    // to a lone run (work units interleave, results must not)
    let (m, k, n) = (48usize, 288usize, 16usize);
    let (a, w) = rand_inputs(0xCAFE, m, k, n);
    let pool = ExecPool::new(4);
    let mut lone = MacroGemm::with_mode(CimMode::Osa).with_pool(ExecPool::new(1));
    let expect = lone.gemm(&a, m, k, &w, n, 0).unwrap().out;
    let mut handles = Vec::new();
    for _ in 0..4 {
        let pool = pool.clone();
        let (a, w) = (a.clone(), w.clone());
        handles.push(std::thread::spawn(move || {
            let mut e = MacroGemm::with_mode(CimMode::Osa).with_pool(pool);
            e.gemm(&a, m, k, &w, n, 0).unwrap().out
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), expect, "concurrent submitters corrupted a result");
    }
}

#[test]
fn pool_shutdown_under_load_loses_no_work() {
    let done = Arc::new(AtomicUsize::new(0));
    {
        let pool = ExecPool::new(3);
        for _ in 0..400 {
            let done = done.clone();
            pool.spawn(move || {
                std::hint::black_box((0..50).sum::<u64>());
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        // pool dropped while most units are still queued: Drop must
        // drain the queue, not abandon it
    }
    assert_eq!(done.load(Ordering::SeqCst), 400, "shutdown dropped queued work units");
}

#[test]
fn panicking_unit_does_not_poison_the_pool() {
    let pool = ExecPool::new(2);
    pool.spawn(|| panic!("deliberate unit panic"));
    // the pool must keep serving afterwards — a GEMM through it works
    let (m, k, n) = (8usize, 144usize, 8usize);
    let (a, w) = rand_inputs(0xF00D, m, k, n);
    let mut e = MacroGemm::with_mode(CimMode::Dcim).with_pool(pool);
    let r = e.gemm(&a, m, k, &w, n, 0).unwrap();
    assert_eq!(r.out.len(), m * n);
}
