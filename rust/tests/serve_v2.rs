//! End-to-end tests of the versioned HTTP surface over a real socket:
//! `POST /v2/infer` (typed options, machine-readable error envelope),
//! `GET /v1/version`, the enriched `/healthz`, and the 405 + `Allow`
//! contract on known paths.  Everything runs on `QGraph::synthetic()`.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::io::json::{parse, JsonValue};
use osa_hcim::nn::QGraph;
use osa_hcim::serve::http;
use osa_hcim::serve::Gateway;
use std::sync::Arc;

fn synth_image(seed: u64) -> Vec<u8> {
    let mut g = osa_hcim::util::prng::SplitMix64::new(seed);
    (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
}

/// A `/v2/infer` body: the image plus a raw JSON options object.
fn v2_body(seed: u64, options: &str) -> String {
    let img = synth_image(seed);
    let mut body = String::with_capacity(img.len() * 4 + 64);
    body.push_str("{\"image\":[");
    for (i, b) in img.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&b.to_string());
    }
    body.push_str("],\"options\":");
    body.push_str(options);
    body.push('}');
    body
}

fn start_gateway(cfg: &SystemConfig) -> (Gateway, String) {
    let gw = Gateway::start(cfg, Arc::new(QGraph::synthetic()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();
    (gw, addr)
}

fn err_field<'a>(doc: &'a JsonValue, field: &str) -> Option<&'a JsonValue> {
    doc.get("error").and_then(|e| e.get(field))
}

#[test]
fn v2_infer_round_trip_with_options() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    cfg.max_batch = 4;
    cfg.batch_timeout_us = 500;
    let (gw, addr) = start_gateway(&cfg);

    // full option set: tier + explicit backend + seed + boundary
    let body = v2_body(1, "{\"tier\":\"gold\",\"backend\":\"macro-dcim\",\"seed\":7}");
    let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let doc = parse(&resp).unwrap();
    assert_eq!(doc.get("api").and_then(JsonValue::as_str), Some("v2"));
    assert_eq!(doc.get("tier").and_then(JsonValue::as_str), Some("gold"));
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-dcim"));
    assert_eq!(doc.get("logits").and_then(JsonValue::as_array).map(|a| a.len()), Some(10));

    // options are optional: bare image serves at the default tier on the
    // active backend
    let body = v2_body(2, "{}");
    let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let doc = parse(&resp).unwrap();
    assert_eq!(doc.get("tier").and_then(JsonValue::as_str), Some("silver"));
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-hybrid"));

    let metrics = gw.shutdown();
    assert_eq!(metrics.requests, 2);
    assert_eq!(metrics.errors, 0);
}

#[test]
fn v2_error_envelope_is_machine_readable() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    let (gw, addr) = start_gateway(&cfg);

    // unknown backend: typed 400 listing every registered backend
    let body = v2_body(1, "{\"backend\":\"macro-gpu\"}");
    let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
    assert_eq!(status, 400, "{resp}");
    let doc = parse(&resp).unwrap();
    assert_eq!(err_field(&doc, "code").and_then(JsonValue::as_str), Some("unknown_backend"));
    let listed: Vec<String> = err_field(&doc, "backends")
        .and_then(JsonValue::as_array)
        .expect("backends list in envelope")
        .iter()
        .filter_map(|v| v.as_str().map(String::from))
        .collect();
    for name in ["macro-hybrid", "macro-dcim", "macro-acim", "macro-fleet", "pjrt"] {
        assert!(listed.iter().any(|n| n == name), "{listed:?} missing {name}");
    }

    // registered but unavailable in this build
    #[cfg(not(feature = "pjrt"))]
    {
        let body = v2_body(1, "{\"backend\":\"pjrt\"}");
        let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
        assert_eq!(status, 400, "{resp}");
        let doc = parse(&resp).unwrap();
        assert_eq!(
            err_field(&doc, "code").and_then(JsonValue::as_str),
            Some("backend_unavailable")
        );
    }

    // malformed options: typed bad_request with a field-naming message
    for (options, needle) in [
        ("{\"tier\":\"bronze\"}", "bronze"),
        ("{\"seed\":-1}", "seed"),
        // beyond 2^53 the f64 wire encoding rounds: rejected, not bent
        ("{\"seed\":100000000000000000}", "seed"),
        ("{\"boundary\":42}", "boundary"),
        ("{\"backend\":7}", "backend"),
        ("[1,2]", "options"),
    ] {
        let body = v2_body(1, options);
        let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
        assert_eq!(status, 400, "{options} -> {resp}");
        let doc = parse(&resp).unwrap();
        assert_eq!(
            err_field(&doc, "code").and_then(JsonValue::as_str),
            Some("bad_request"),
            "{resp}"
        );
        let msg = err_field(&doc, "message").and_then(JsonValue::as_str).unwrap();
        assert!(msg.contains(needle), "message {msg:?} should name {needle:?}");
    }

    let metrics = gw.shutdown();
    assert_eq!(metrics.requests, 0, "rejected requests must never reach a worker");
}

#[test]
fn v2_seed_and_boundary_options_steer_the_datapath() {
    // HCIM mode so the boundary override is live; noise is on by default
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Hcim;
    cfg.workers = 1;
    let (gw, addr) = start_gateway(&cfg);

    let logits_of = |options: &str| -> Vec<String> {
        let body = v2_body(42, options);
        let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
        assert_eq!(status, 200, "{options} -> {resp}");
        let doc = parse(&resp).unwrap();
        doc.get("logits")
            .and_then(JsonValue::as_array)
            .unwrap()
            .iter()
            .map(|v| format!("{:?}", v.as_f64().unwrap()))
            .collect()
    };

    // same seed twice: bit-stable through the wire
    let a1 = logits_of("{\"seed\":5}");
    let a2 = logits_of("{\"seed\":5}");
    assert_eq!(a1, a2, "same seed must reproduce identical logits");
    // a different seed shifts the analog noise
    let b = logits_of("{\"seed\":6}");
    assert_ne!(a1, b, "seed override had no effect");
    // a finer boundary changes the digital/analog split (B=0 is the
    // all-digital extreme; B=10 discards most digital orders)
    let fine = logits_of("{\"seed\":5,\"boundary\":0}");
    let coarse = logits_of("{\"seed\":5,\"boundary\":10}");
    assert_ne!(fine, coarse, "boundary override had no effect");

    gw.shutdown();
}

#[test]
fn wrong_method_on_known_path_is_405_with_allow() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    let (gw, addr) = start_gateway(&cfg);

    let mut client = http::Client::connect(&addr).unwrap();
    // GET on a POST-only route
    let (status, headers, body) =
        client.request_with_headers("GET", "/v2/infer", None).unwrap();
    assert_eq!(status, 405, "{body}");
    assert_eq!(headers.get("allow").map(String::as_str), Some("POST"));
    // POST on a GET-only route — and keep-alive survives the 405
    let (status, headers, _) =
        client.request_with_headers("POST", "/metrics", Some("{}")).unwrap();
    assert_eq!(status, 405);
    assert_eq!(headers.get("allow").map(String::as_str), Some("GET"));
    let (status, _, _) = client.request_with_headers("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200, "connection must survive a 405");
    // unknown path is still a plain 404
    let (status, headers, _) = client.request_with_headers("GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    assert!(headers.get("allow").is_none(), "404 must not advertise methods");

    gw.shutdown();
}

#[test]
fn version_and_healthz_report_the_running_engine() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    cfg.engine_threads = 2;
    cfg.backend = "macro-dcim".to_string();
    let (gw, addr) = start_gateway(&cfg);

    let (status, body) = http::request(&addr, "GET", "/v1/version", None).unwrap();
    assert_eq!(status, 200, "{body}");
    let doc = parse(&body).unwrap();
    assert_eq!(
        doc.get("version").and_then(JsonValue::as_str),
        Some(env!("CARGO_PKG_VERSION"))
    );
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-dcim"));
    assert_eq!(doc.get("engine_threads").and_then(JsonValue::as_i64), Some(2));
    let backends = doc.get("backends").and_then(JsonValue::as_array).unwrap();
    assert_eq!(backends.len(), 5);
    // additive fleet-era keys: structured capabilities + [fleet] geometry
    let caps = doc.get("capabilities").expect("capabilities object");
    assert_eq!(caps.get("mode").and_then(JsonValue::as_str), Some("dcim"));
    assert_eq!(caps.get("macros").and_then(JsonValue::as_i64), Some(1));
    let fleet = doc.get("fleet").expect("fleet object");
    assert_eq!(fleet.get("macros").and_then(JsonValue::as_i64), Some(1));
    assert_eq!(fleet.get("placement").and_then(JsonValue::as_str), Some("auto"));
    #[cfg(not(feature = "pjrt"))]
    {
        let pjrt = backends
            .iter()
            .find(|b| b.get("name").and_then(JsonValue::as_str) == Some("pjrt"))
            .expect("pjrt listed");
        assert_eq!(pjrt.get("available").and_then(JsonValue::as_bool), Some(false));
    }

    let (status, body) = http::request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let doc = parse(&body).unwrap();
    assert_eq!(doc.get("status").and_then(JsonValue::as_str), Some("ok"));
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-dcim"));
    assert_eq!(doc.get("engine_threads").and_then(JsonValue::as_i64), Some(2));
    assert_eq!(
        doc.get("version").and_then(JsonValue::as_str),
        Some(env!("CARGO_PKG_VERSION"))
    );

    gw.shutdown();
}

#[test]
fn v1_adapter_serves_default_tier_and_backend_tag() {
    // the /v1 surface rides the same typed path: configured default
    // tier applies, responses carry the serving backend
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    cfg.default_tier = osa_hcim::serve::Tier::Gold;
    let (gw, addr) = start_gateway(&cfg);

    let img = synth_image(3);
    // v1 body with NO tier field: the configured default must apply
    let mut body = String::from("{\"image\":[");
    for (i, b) in img.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&b.to_string());
    }
    body.push_str("]}");
    let (status, resp) = http::request(&addr, "POST", "/v1/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let doc = parse(&resp).unwrap();
    assert_eq!(doc.get("tier").and_then(JsonValue::as_str), Some("gold"));
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-hybrid"));

    gw.shutdown();
}
