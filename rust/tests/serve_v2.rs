//! End-to-end tests of the versioned HTTP surface over a real socket:
//! `POST /v2/infer` (typed options, machine-readable error envelope),
//! `GET /v1/version`, the enriched `/healthz`, `GET /v2/device` (the
//! analog device model and swept governor floors), and the 405 +
//! `Allow` contract on known paths.  Everything runs on
//! `QGraph::synthetic()`.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::io::json::{parse, JsonValue};
use osa_hcim::nn::QGraph;
use osa_hcim::serve::http;
use osa_hcim::serve::Gateway;
use std::sync::Arc;

fn synth_image(seed: u64) -> Vec<u8> {
    let mut g = osa_hcim::util::prng::SplitMix64::new(seed);
    (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
}

/// A `/v2/infer` body: the image plus a raw JSON options object.
fn v2_body(seed: u64, options: &str) -> String {
    let img = synth_image(seed);
    let mut body = String::with_capacity(img.len() * 4 + 64);
    body.push_str("{\"image\":[");
    for (i, b) in img.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&b.to_string());
    }
    body.push_str("],\"options\":");
    body.push_str(options);
    body.push('}');
    body
}

fn start_gateway(cfg: &SystemConfig) -> (Gateway, String) {
    let gw = Gateway::start(cfg, Arc::new(QGraph::synthetic()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();
    (gw, addr)
}

fn err_field<'a>(doc: &'a JsonValue, field: &str) -> Option<&'a JsonValue> {
    doc.get("error").and_then(|e| e.get(field))
}

#[test]
fn v2_infer_round_trip_with_options() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    cfg.max_batch = 4;
    cfg.batch_timeout_us = 500;
    let (gw, addr) = start_gateway(&cfg);

    // full option set: tier + explicit backend + seed + boundary
    let body = v2_body(1, "{\"tier\":\"gold\",\"backend\":\"macro-dcim\",\"seed\":7}");
    let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let doc = parse(&resp).unwrap();
    assert_eq!(doc.get("api").and_then(JsonValue::as_str), Some("v2"));
    assert_eq!(doc.get("tier").and_then(JsonValue::as_str), Some("gold"));
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-dcim"));
    assert_eq!(doc.get("logits").and_then(JsonValue::as_array).map(|a| a.len()), Some(10));

    // options are optional: bare image serves at the default tier on the
    // active backend
    let body = v2_body(2, "{}");
    let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let doc = parse(&resp).unwrap();
    assert_eq!(doc.get("tier").and_then(JsonValue::as_str), Some("silver"));
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-hybrid"));

    let metrics = gw.shutdown();
    assert_eq!(metrics.requests, 2);
    assert_eq!(metrics.errors, 0);
}

#[test]
fn v2_error_envelope_is_machine_readable() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    let (gw, addr) = start_gateway(&cfg);

    // unknown backend: typed 400 listing every registered backend
    let body = v2_body(1, "{\"backend\":\"macro-gpu\"}");
    let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
    assert_eq!(status, 400, "{resp}");
    let doc = parse(&resp).unwrap();
    assert_eq!(err_field(&doc, "code").and_then(JsonValue::as_str), Some("unknown_backend"));
    let listed: Vec<String> = err_field(&doc, "backends")
        .and_then(JsonValue::as_array)
        .expect("backends list in envelope")
        .iter()
        .filter_map(|v| v.as_str().map(String::from))
        .collect();
    for name in ["macro-hybrid", "macro-dcim", "macro-acim", "macro-fleet", "pjrt"] {
        assert!(listed.iter().any(|n| n == name), "{listed:?} missing {name}");
    }

    // registered but unavailable in this build
    #[cfg(not(feature = "pjrt"))]
    {
        let body = v2_body(1, "{\"backend\":\"pjrt\"}");
        let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
        assert_eq!(status, 400, "{resp}");
        let doc = parse(&resp).unwrap();
        assert_eq!(
            err_field(&doc, "code").and_then(JsonValue::as_str),
            Some("backend_unavailable")
        );
    }

    // malformed options: typed bad_request with a field-naming message
    for (options, needle) in [
        ("{\"tier\":\"bronze\"}", "bronze"),
        ("{\"seed\":-1}", "seed"),
        // beyond 2^53 the f64 wire encoding rounds: rejected, not bent
        ("{\"seed\":100000000000000000}", "seed"),
        ("{\"boundary\":42}", "boundary"),
        ("{\"backend\":7}", "backend"),
        ("[1,2]", "options"),
    ] {
        let body = v2_body(1, options);
        let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
        assert_eq!(status, 400, "{options} -> {resp}");
        let doc = parse(&resp).unwrap();
        assert_eq!(
            err_field(&doc, "code").and_then(JsonValue::as_str),
            Some("bad_request"),
            "{resp}"
        );
        let msg = err_field(&doc, "message").and_then(JsonValue::as_str).unwrap();
        assert!(msg.contains(needle), "message {msg:?} should name {needle:?}");
    }

    let metrics = gw.shutdown();
    assert_eq!(metrics.requests, 0, "rejected requests must never reach a worker");
}

#[test]
fn v2_seed_and_boundary_options_steer_the_datapath() {
    // HCIM mode so the boundary override is live; noise is on by default
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Hcim;
    cfg.workers = 1;
    let (gw, addr) = start_gateway(&cfg);

    let logits_of = |options: &str| -> Vec<String> {
        let body = v2_body(42, options);
        let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
        assert_eq!(status, 200, "{options} -> {resp}");
        let doc = parse(&resp).unwrap();
        doc.get("logits")
            .and_then(JsonValue::as_array)
            .unwrap()
            .iter()
            .map(|v| format!("{:?}", v.as_f64().unwrap()))
            .collect()
    };

    // same seed twice: bit-stable through the wire
    let a1 = logits_of("{\"seed\":5}");
    let a2 = logits_of("{\"seed\":5}");
    assert_eq!(a1, a2, "same seed must reproduce identical logits");
    // a different seed shifts the analog noise
    let b = logits_of("{\"seed\":6}");
    assert_ne!(a1, b, "seed override had no effect");
    // a finer boundary changes the digital/analog split (B=0 is the
    // all-digital extreme; B=10 discards most digital orders)
    let fine = logits_of("{\"seed\":5,\"boundary\":0}");
    let coarse = logits_of("{\"seed\":5,\"boundary\":10}");
    assert_ne!(fine, coarse, "boundary override had no effect");

    gw.shutdown();
}

#[test]
fn wrong_method_on_known_path_is_405_with_allow() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    let (gw, addr) = start_gateway(&cfg);

    let mut client = http::Client::connect(&addr).unwrap();
    // GET on a POST-only route
    let (status, headers, body) =
        client.request_with_headers("GET", "/v2/infer", None).unwrap();
    assert_eq!(status, 405, "{body}");
    assert_eq!(headers.get("allow").map(String::as_str), Some("POST"));
    // POST on a GET-only route — and keep-alive survives the 405
    let (status, headers, _) =
        client.request_with_headers("POST", "/metrics", Some("{}")).unwrap();
    assert_eq!(status, 405);
    assert_eq!(headers.get("allow").map(String::as_str), Some("GET"));
    let (status, _, _) = client.request_with_headers("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200, "connection must survive a 405");
    // unknown path is still a plain 404
    let (status, headers, _) = client.request_with_headers("GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    assert!(headers.get("allow").is_none(), "404 must not advertise methods");

    gw.shutdown();
}

#[test]
fn version_and_healthz_report_the_running_engine() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    cfg.engine_threads = 2;
    cfg.backend = "macro-dcim".to_string();
    let (gw, addr) = start_gateway(&cfg);

    let (status, body) = http::request(&addr, "GET", "/v1/version", None).unwrap();
    assert_eq!(status, 200, "{body}");
    let doc = parse(&body).unwrap();
    assert_eq!(
        doc.get("version").and_then(JsonValue::as_str),
        Some(env!("CARGO_PKG_VERSION"))
    );
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-dcim"));
    assert_eq!(doc.get("engine_threads").and_then(JsonValue::as_i64), Some(2));
    let backends = doc.get("backends").and_then(JsonValue::as_array).unwrap();
    assert_eq!(backends.len(), 5);
    // additive fleet-era keys: structured capabilities + [fleet] geometry
    let caps = doc.get("capabilities").expect("capabilities object");
    assert_eq!(caps.get("mode").and_then(JsonValue::as_str), Some("dcim"));
    assert_eq!(caps.get("macros").and_then(JsonValue::as_i64), Some(1));
    // additive device-era key: the analog device model in force
    let dev = caps.get("device").expect("device block in capabilities");
    assert_eq!(dev.get("model").and_then(JsonValue::as_str), Some("gaussian-thermal"));
    assert_eq!(dev.get("sigma").and_then(JsonValue::as_f64), Some(osa_hcim::spec::SIGMA_CODE));
    assert_eq!(dev.get("s_ou").and_then(JsonValue::as_i64), Some(0));
    let fleet = doc.get("fleet").expect("fleet object");
    assert_eq!(fleet.get("macros").and_then(JsonValue::as_i64), Some(1));
    assert_eq!(fleet.get("placement").and_then(JsonValue::as_str), Some("auto"));
    #[cfg(not(feature = "pjrt"))]
    {
        let pjrt = backends
            .iter()
            .find(|b| b.get("name").and_then(JsonValue::as_str) == Some("pjrt"))
            .expect("pjrt listed");
        assert_eq!(pjrt.get("available").and_then(JsonValue::as_bool), Some(false));
    }

    let (status, body) = http::request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let doc = parse(&body).unwrap();
    assert_eq!(doc.get("status").and_then(JsonValue::as_str), Some("ok"));
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-dcim"));
    assert_eq!(doc.get("engine_threads").and_then(JsonValue::as_i64), Some(2));
    assert_eq!(
        doc.get("version").and_then(JsonValue::as_str),
        Some(env!("CARGO_PKG_VERSION"))
    );
    // additive device-era key on the liveness probe too
    let dev = doc.get("device").expect("device block in healthz");
    assert_eq!(dev.get("model").and_then(JsonValue::as_str), Some("gaussian-thermal"));
    assert_eq!(dev.get("s_ou").and_then(JsonValue::as_i64), Some(0));

    gw.shutdown();
}

#[test]
fn v2_device_reports_model_and_unbounded_floors() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    cfg.device_model = "capacitor-mismatch".to_string();
    cfg.device_sigma = Some(0.12);
    let (gw, addr) = start_gateway(&cfg);

    let (status, body) = http::request(&addr, "GET", "/v2/device", None).unwrap();
    assert_eq!(status, 200, "{body}");
    let doc = parse(&body).unwrap();
    let dev = doc.get("device").expect("device object");
    assert_eq!(dev.get("model").and_then(JsonValue::as_str), Some("capacitor-mismatch"));
    assert_eq!(dev.get("sigma").and_then(JsonValue::as_f64), Some(0.12));
    let sweep = doc.get("sweep").expect("sweep object");
    assert_eq!(sweep.get("floors_loaded").and_then(JsonValue::as_bool), Some(false));
    assert_eq!(sweep.get("report").and_then(JsonValue::as_str), Some(""));
    // no sweep report: every tier's floor cap renders as null and the
    // effective level cap is the configured governor max_level
    let tiers = doc.get("tiers").expect("tiers object");
    for tier in ["gold", "silver", "batch"] {
        let t = tiers.get(tier).expect("tier entry");
        assert!(matches!(t.get("floor_cap"), Some(JsonValue::Null)), "{body}");
        assert_eq!(
            t.get("level_cap").and_then(JsonValue::as_i64),
            Some(cfg.gov_max_level as i64),
            "{body}"
        );
    }
    // the governor's metrics view agrees: floors present, not loaded
    let (_, body) = http::request(&addr, "GET", "/metrics", None).unwrap();
    let doc = parse(&body).unwrap();
    let gov = doc.get("governor").expect("governor object");
    let floors = gov.get("floors").expect("floors object");
    assert_eq!(floors.get("loaded").and_then(JsonValue::as_bool), Some(false));
    // wrong method: 405 naming GET
    let mut client = http::Client::connect(&addr).unwrap();
    let (status, headers, _) =
        client.request_with_headers("POST", "/v2/device", Some("{}")).unwrap();
    assert_eq!(status, 405);
    assert_eq!(headers.get("allow").map(String::as_str), Some("GET"));

    gw.shutdown();
}

#[test]
fn swept_floors_load_into_the_serving_governor() {
    use osa_hcim::device::sweep::{LadderPoint, SweepGrid, SweepReport};

    // a sweep report whose corner says: batch collapses past level 1
    let report = SweepReport {
        model: "gaussian-thermal".to_string(),
        s_ou: 0,
        grid: SweepGrid {
            boundaries: vec![10],
            sigmas: vec![0.45],
            mc_seeds: 1,
            images: 2,
            corner_sigma: 0.45,
        },
        surface: Vec::new(),
        ladder: vec![
            LadderPoint { tier: "batch", level: 0, accuracy: 0.99 },
            LadderPoint { tier: "batch", level: 1, accuracy: 0.95 },
            LadderPoint { tier: "batch", level: 2, accuracy: 0.40 },
        ],
    };
    let path = std::env::temp_dir().join("osa_hcim_serve_v2_sweep_floors.json");
    std::fs::write(&path, report.to_json().to_string_compact()).unwrap();

    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    cfg.device_sweep_report = path.to_string_lossy().into_owned();
    cfg.device_sla_batch = 0.9;
    let (gw, addr) = start_gateway(&cfg);

    let (status, body) = http::request(&addr, "GET", "/v2/device", None).unwrap();
    assert_eq!(status, 200, "{body}");
    let doc = parse(&body).unwrap();
    let sweep = doc.get("sweep").expect("sweep object");
    assert_eq!(sweep.get("floors_loaded").and_then(JsonValue::as_bool), Some(true), "{body}");
    assert_eq!(sweep.get("floor_corner_sigma").and_then(JsonValue::as_f64), Some(0.45));
    let tiers = doc.get("tiers").expect("tiers object");
    let batch = tiers.get("batch").expect("batch tier");
    assert_eq!(batch.get("floor_cap").and_then(JsonValue::as_i64), Some(1), "{body}");
    assert_eq!(batch.get("level_cap").and_then(JsonValue::as_i64), Some(1), "{body}");
    // tiers without an SLA stay unbounded by the report
    let gold = tiers.get("gold").expect("gold tier");
    assert!(matches!(gold.get("floor_cap"), Some(JsonValue::Null)), "{body}");

    gw.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn v1_adapter_serves_default_tier_and_backend_tag() {
    // the /v1 surface rides the same typed path: configured default
    // tier applies, responses carry the serving backend
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    cfg.default_tier = osa_hcim::serve::Tier::Gold;
    let (gw, addr) = start_gateway(&cfg);

    let img = synth_image(3);
    // v1 body with NO tier field: the configured default must apply
    let mut body = String::from("{\"image\":[");
    for (i, b) in img.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&b.to_string());
    }
    body.push_str("]}");
    let (status, resp) = http::request(&addr, "POST", "/v1/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let doc = parse(&resp).unwrap();
    assert_eq!(doc.get("tier").and_then(JsonValue::as_str), Some("gold"));
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-hybrid"));

    gw.shutdown();
}
