//! PR-9 energy-model guarantees (DESIGN.md §15):
//!
//! * **compact is the default and is inert** — the stock config reports
//!   `cost_model = "compact"`, prices zero movement (bit-zero f64s) and
//!   produces logits bit-identical to the hierarchy model, which only
//!   adds movement terms on top;
//! * **hierarchy totals are deterministic** — per-level movement energy
//!   and the joule total reproduce the same f64 bits across repeat
//!   runs, thread counts and fleet sizes K in {1, 2, 4};
//! * **joule-grounded governor** — the watts signal includes fleet
//!   transfer energy: a budget that a K=1 run clears is tripped by the
//!   same model sharded K=4, purely because of inter-macro transfer;
//! * **serve surface** — `GET /v2/energy` renders the per-layer
//!   per-level trace, `/metrics` keeps every pre-existing energy key
//!   while adding the `energy` block, the Prometheus exposition gains
//!   `osa_energy_joules_total{component,level}`, and an
//!   `energy_budget_w` breach degrades (then restores) tiers
//!   end-to-end over HTTP.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::SystemConfig;
use osa_hcim::energy::hierarchy::NUM_LEVELS;
use osa_hcim::engine::Engine;
use osa_hcim::io::json::{parse, JsonValue};
use osa_hcim::nn::{Op, QConv, QFc, QGraph};
use osa_hcim::obs;
use osa_hcim::serve::http;
use osa_hcim::serve::{Gateway, Governor, GovernorConfig, Tier};
use osa_hcim::util::prng::SplitMix64;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn synth_batch(n: usize) -> Vec<u8> {
    let mut g = SplitMix64::new(0xF1EE7);
    (0..n * 32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
}

/// A `/v2/infer` body: the image plus a raw JSON options object.
fn v2_body(seed: u64, options: &str) -> String {
    let mut g = SplitMix64::new(seed);
    let img: Vec<u8> = (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect();
    let mut body = String::with_capacity(img.len() * 4 + 64);
    body.push_str("{\"image\":[");
    for (i, b) in img.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&b.to_string());
    }
    body.push_str("],\"options\":");
    body.push_str(options);
    body.push('}');
    body
}

/// Two-conv graph whose second conv contracts over k = 288 > 144 macro
/// columns — two K-tiles, so a `residency_tiles = 1` fleet must split
/// its columns across macros and charge inter-macro transfer.
fn split_k_graph() -> QGraph {
    let mut g = SplitMix64::new(0x5711F);
    let mut conv = |name: &str, cin: usize, cout: usize| QConv {
        name: name.into(),
        kh: 3,
        kw: 3,
        cin,
        cout,
        stride: 1,
        act_scale: 1.0 / 255.0,
        w_scale: 0.05,
        w_q: (0..cout * 9 * cin).map(|_| g.next_range_i32(-64, 64)).collect(),
        bias_q: vec![0; cout],
    };
    let stem = conv("stem", 3, 32);
    let deep = conv("deep", 32, 16);
    let fc = QFc {
        cin: 16,
        cout: 10,
        act_scale: 0.05,
        w_scale: 0.05,
        w_q: (0..10 * 16).map(|_| g.next_range_i32(-64, 64)).collect(),
        bias_q: vec![0; 10],
    };
    let mut convs = BTreeMap::new();
    convs.insert("stem".to_string(), stem);
    convs.insert("deep".to_string(), deep);
    QGraph {
        convs,
        fc,
        ops: vec![
            Op::QConv { name: "stem".into(), relu: true },
            Op::QConv { name: "deep".into(), relu: true },
            Op::Gap,
            Op::QFc,
        ],
        num_classes: 10,
    }
}

/// Forward the synthetic graph under `hardware_model = model` and
/// return (logit bits, boundary hist, per-level movement bits, total
/// joules).
fn forward_model(model: &str, threads: usize) -> (Vec<u32>, [u64; 16], [u64; NUM_LEVELS], f64) {
    let mut cfg = SystemConfig::default();
    cfg.hardware_model = model.to_string();
    let n = 2usize;
    let images = synth_batch(n);
    let engine = Engine::builder()
        .config(cfg)
        .graph(Arc::new(QGraph::synthetic()))
        .backend("macro-hybrid")
        .fleet(1)
        .threads(threads)
        .build()
        .unwrap();
    let mut exec = engine.executor().unwrap();
    exec.preplan().unwrap();
    let (logits, stats) = exec.forward(&images, n).unwrap();
    let movement_bits: [u64; NUM_LEVELS] =
        std::array::from_fn(|i| stats.account.breakdown.movement_fj[i].to_bits());
    (
        logits.iter().map(|x| x.to_bits()).collect(),
        stats.b_hist,
        movement_bits,
        stats.account.total_energy_j(),
    )
}

#[test]
fn compact_default_is_movement_free_and_logit_identical_to_hierarchy() {
    assert_eq!(SystemConfig::default().hardware_model, "compact", "compact must stay the default");
    for threads in [1usize, 4] {
        let (lc, hc, mc, ec) = forward_model("compact", threads);
        let (lh, hh, mh, eh) = forward_model("hierarchy", threads);
        // compact prices no movement, down to the bit pattern
        assert_eq!(mc, [0u64; NUM_LEVELS], "compact model must not price movement");
        // the hierarchy model is purely additive on top of the same
        // numerics: identical logits and boundary choices, extra joules
        assert_eq!(lc, lh, "hierarchy model must not perturb logits ({threads} threads)");
        assert_eq!(hc, hh, "hierarchy model must not perturb boundaries ({threads} threads)");
        assert!(mh.iter().any(|&b| f64::from_bits(b) > 0.0), "hierarchy must price movement");
        assert!(eh > ec, "movement terms must increase the joule total");
    }
}

#[test]
fn hierarchy_totals_are_thread_and_fleet_merge_invariant() {
    let graph = Arc::new(split_k_graph());
    let images = synth_batch(2);
    for k in [1usize, 2, 4] {
        let run = |threads: usize| -> (u64, [u64; NUM_LEVELS]) {
            let mut cfg = SystemConfig::default();
            cfg.fleet_residency_tiles = 1; // force the deep conv to split
            cfg.hardware_model = "hierarchy".to_string();
            let engine = Engine::builder()
                .config(cfg)
                .graph(graph.clone())
                .backend("macro-fleet")
                .fleet(k)
                .threads(threads)
                .build()
                .unwrap();
            let mut exec = engine.executor().unwrap();
            exec.preplan().unwrap();
            let (_, stats) = exec.forward(&images, 2).unwrap();
            let mv: [u64; NUM_LEVELS] =
                std::array::from_fn(|i| stats.account.breakdown.movement_fj[i].to_bits());
            (stats.account.total_energy_j().to_bits(), mv)
        };
        let (e_a, m_a) = run(1);
        let (e_b, m_b) = run(1);
        let (e_c, m_c) = run(4);
        assert_eq!(e_a, e_b, "K={k}: repeat run shifts the hierarchy joule bits");
        assert_eq!(e_a, e_c, "K={k}: thread count shifts the hierarchy joule bits");
        assert_eq!(m_a, m_b, "K={k}: repeat run shifts per-level movement bits");
        assert_eq!(m_a, m_c, "K={k}: thread count shifts per-level movement bits");
        assert!(m_a.iter().any(|&b| f64::from_bits(b) > 0.0), "K={k}: movement must be priced");
    }
}

/// Satellite 1: the governor's watts signal is grounded in the full
/// account — fleet transfer included.  The same model on the same
/// budget clears at K=1 and trips at K=4, where split-K transfer is
/// the only extra energy.
#[test]
fn governor_budget_trips_on_transfer_heavy_fleet() {
    let graph = Arc::new(split_k_graph());
    let images = synth_batch(2);
    let run = |k: usize| -> (f64, f64) {
        let mut cfg = SystemConfig::default();
        cfg.fleet_residency_tiles = 1;
        let engine = Engine::builder()
            .config(cfg)
            .graph(graph.clone())
            .backend("macro-fleet")
            .fleet(k)
            .threads(1)
            .build()
            .unwrap();
        let mut exec = engine.executor().unwrap();
        exec.preplan().unwrap();
        let (_, stats) = exec.forward(&images, 2).unwrap();
        (stats.account.total_energy_j(), stats.account.transfer_fj)
    };
    let (e1, t1) = run(1);
    let (e4, t4) = run(4);
    assert_eq!(t1, 0.0, "K=1 has no inter-macro hops");
    assert!(t4 > 0.0, "K=4 split-K must charge transfer");
    assert!(e4 > e1, "transfer must be part of the joule total");

    // the same work over the same wall window: watts differ only by
    // the transfer term, and a budget between the two separates them
    let (w1, w4) = (e1 / 0.1, e4 / 0.1);
    let gcfg = |budget: f64| GovernorConfig {
        enabled: true,
        high_watermark: 0.75,
        low_watermark: 0.25,
        max_level: 3,
        hold: Duration::ZERO,
        energy_budget_w: budget,
    };
    const CAL: [i32; 5] = [0, 0, 32, 94, 1024];
    let budget = 0.5 * (w1 + w4);

    let g = Governor::new(&CAL, gcfg(budget));
    for _ in 0..3 {
        g.observe(0.0, w1);
    }
    assert_eq!(g.level(Tier::Batch), 0, "K=1 watts must clear the budget");
    g.observe(0.0, w4);
    assert!(g.level(Tier::Batch) >= 1, "K=4 transfer watts must trip the budget");
    assert_eq!(g.level(Tier::Gold), 0, "gold never degrades");
    // watts back under budget: the breach drains
    for _ in 0..16 {
        g.observe(0.0, w1);
    }
    assert_eq!(g.level(Tier::Batch), 0, "levels restore once watts drop");

    // a budget above the K=4 draw never trips at all
    let g = Governor::new(&CAL, gcfg(w4 * 2.0));
    for _ in 0..3 {
        g.observe(0.0, w4);
    }
    assert_eq!(g.level(Tier::Batch), 0, "a generous budget must not trip");
}

fn get_metrics(addr: &str) -> JsonValue {
    let (status, body) = http::request(addr, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200, "metrics endpoint failed: {body}");
    parse(&body).unwrap()
}

fn gov_level(metrics: &JsonValue, tier: &str) -> i64 {
    metrics
        .get("governor")
        .and_then(|g| g.get("tiers"))
        .and_then(|t| t.get(tier))
        .and_then(|t| t.get("level"))
        .and_then(JsonValue::as_i64)
        .expect("governor level in /metrics")
}

/// End-to-end acceptance: a hierarchy-model fleet serves `/v2/energy`
/// whose per-layer per-level trace is reportable before any traffic,
/// and a tiny `energy_budget_w` degrades tiers while requests flow,
/// then restores once the watts estimate decays.
#[test]
fn v2_energy_trace_and_budget_degrade_end_to_end() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    cfg.max_batch = 4;
    cfg.batch_timeout_us = 500;
    cfg.backend = "macro-fleet".to_string();
    cfg.fleet_macros = 4;
    cfg.fleet_residency_tiles = 1;
    cfg.hardware_model = "hierarchy".to_string();
    cfg.energy_budget_w = 1e-9; // any modeled flow breaches
    cfg.gov_hold_ms = 10;
    let gw = Gateway::start(&cfg, Arc::new(split_k_graph()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();

    // capability surface flips with the model
    let (status, body) = http::request(&addr, "GET", "/v1/version", None).unwrap();
    assert_eq!(status, 200, "{body}");
    let doc = parse(&body).unwrap();
    let caps = doc.get("capabilities").expect("capabilities");
    assert_eq!(caps.get("cost_model").and_then(JsonValue::as_str), Some("hierarchy"));
    assert_eq!(caps.get("memory_levels").and_then(JsonValue::as_i64), Some(5));

    // the trace is reportable before any traffic
    let (status, body) = http::request(&addr, "GET", "/v2/energy", None).unwrap();
    assert_eq!(status, 200, "{body}");
    let doc = parse(&body).unwrap();
    assert_eq!(doc.get("model").and_then(JsonValue::as_str), Some("hierarchy"));
    let hw = doc.get("hardware").expect("hardware stack");
    for level in ["cell_group", "acc_rf", "weight_sram", "act_sram", "dram"] {
        let lv = hw.get(level).unwrap_or_else(|| panic!("level {level} missing: {body}"));
        // cell_group reads are folded into the bit-MAC constant and
        // priced at 0, so the always-positive anchor is the write cost
        assert!(lv.get("write_fj").and_then(JsonValue::as_f64).unwrap() > 0.0);
    }
    let layers = doc.get("layers").and_then(JsonValue::as_array).expect("layers");
    assert_eq!(layers.len(), 2, "stem + deep conv: {body}");
    for l in layers {
        let levels = l.get("levels").expect("per-level counts");
        for level in ["cell_group", "acc_rf", "weight_sram", "act_sram", "dram"] {
            let lv = levels.get(level).expect("level entry");
            assert!(lv.get("reads").and_then(JsonValue::as_f64).unwrap() > 0.0, "{body}");
        }
        assert!(l.get("movement_fj").and_then(JsonValue::as_f64).unwrap() > 0.0, "{body}");
    }
    // the deep conv (k = 288) splits across macros -> inter-macro hops
    let deep = layers
        .iter()
        .find(|l| l.get("name").and_then(JsonValue::as_str) == Some("deep"))
        .expect("deep layer");
    assert!(deep.get("hop_words").and_then(JsonValue::as_f64).unwrap() > 0.0, "{body}");
    let trace = doc.get("trace").expect("trace totals");
    assert!(trace.get("movement_fj").and_then(JsonValue::as_f64).unwrap() > 0.0);

    // flow requests until the budget breach degrades the batch tier
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut seed = 0u64;
    loop {
        seed += 1;
        let (status, resp) =
            http::request(&addr, "POST", "/v2/infer", Some(&v2_body(seed, "{}"))).unwrap();
        assert_eq!(status, 200, "{resp}");
        let rdoc = parse(&resp).unwrap();
        assert!(
            rdoc.get("energy_j").and_then(JsonValue::as_f64).unwrap() > 0.0,
            "per-request energy missing: {resp}"
        );
        let m = get_metrics(&addr);
        assert_eq!(gov_level(&m, "gold"), 0, "gold must never degrade");
        if gov_level(&m, "batch") >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "budget breach never degraded batch tier");
        std::thread::sleep(Duration::from_millis(30));
    }

    // live account now backs the trace endpoint
    let (_, body) = http::request(&addr, "GET", "/v2/energy", None).unwrap();
    let doc = parse(&body).unwrap();
    let account = doc.get("account").expect("account block");
    assert!(account.get("energy_j").and_then(JsonValue::as_f64).unwrap() > 0.0, "{body}");
    assert!(account.get("requests").and_then(JsonValue::as_f64).unwrap() >= 1.0, "{body}");
    assert!(account.get("energy_per_request_j").and_then(JsonValue::as_f64).unwrap() > 0.0);
    assert!(account.get("movement_fj").and_then(JsonValue::as_f64).unwrap() > 0.0, "{body}");
    assert!(account.get("transfer_fj").and_then(JsonValue::as_f64).unwrap() > 0.0, "{body}");

    // traffic stops -> the windowed watts estimate decays below the
    // budget -> levels restore
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let m = get_metrics(&addr);
        if gov_level(&m, "batch") == 0 {
            break;
        }
        assert!(Instant::now() < deadline, "governor never restored after idle decay");
        std::thread::sleep(Duration::from_millis(100));
    }
    gw.shutdown();
}

/// Satellite 6: `/metrics` keeps every pre-existing energy key, adds
/// the `energy` block and per-layer `movement_j`, and the Prometheus
/// exposition carries the per-component/per-level joule counters.
#[test]
fn metrics_keeps_energy_keys_and_adds_energy_block() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    cfg.max_batch = 2;
    cfg.batch_timeout_us = 500;
    let gw = Gateway::start(&cfg, Arc::new(QGraph::synthetic()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();
    let (status, resp) =
        http::request(&addr, "POST", "/v2/infer", Some(&v2_body(9, "{}"))).unwrap();
    assert_eq!(status, 200, "{resp}");

    let m = get_metrics(&addr);
    // every pre-existing energy key, key for key
    for key in ["watts", "tops_per_watt", "requests", "layers", "fleet"] {
        assert!(m.get(key).is_some(), "pre-existing key {key} must survive");
    }
    let layers = m.get("layers").expect("layers block");
    if let JsonValue::Object(map) = layers {
        assert!(!map.is_empty(), "layer attribution must be populated");
        for (name, st) in map {
            assert!(st.get("energy_j").is_some(), "layer {name} lost energy_j");
            let mv = st.get("movement_j").and_then(JsonValue::as_array);
            assert_eq!(mv.map(Vec::len), Some(NUM_LEVELS), "layer {name} movement_j");
        }
    } else {
        panic!("layers must be an object");
    }
    // the new block: compact default -> movement and transfer are zero
    let e = m.get("energy").expect("energy block");
    assert_eq!(e.get("model").and_then(JsonValue::as_str), Some("compact"));
    assert!(e.get("total_j").and_then(JsonValue::as_f64).unwrap() > 0.0);
    assert_eq!(e.get("movement_fj").and_then(JsonValue::as_f64), Some(0.0));
    assert!(e.get("per_inference_j").and_then(JsonValue::as_f64).unwrap() > 0.0);
    let by_level = e.get("movement_levels_fj").expect("per-level movement");
    for level in ["cell_group", "acc_rf", "weight_sram", "act_sram", "dram"] {
        assert_eq!(by_level.get(level).and_then(JsonValue::as_f64), Some(0.0));
    }

    // Prometheus: the joule counters ride the same scrubbed writer
    let (status, text) = http::request(&addr, "GET", "/metrics?format=prometheus", None).unwrap();
    assert_eq!(status, 200);
    let doc = obs::parse_exposition(&text).unwrap_or_else(|e| panic!("must parse: {e}\n{text}"));
    for comp in ["digital", "adc", "dac", "nq", "ose", "ctrl"] {
        let v = doc.value("osa_energy_joules_total", &[("component", comp), ("level", "macro")]);
        assert!(v.is_some(), "missing component {comp}:\n{text}");
    }
    let adc = doc
        .value("osa_energy_joules_total", &[("component", "adc"), ("level", "macro")])
        .unwrap();
    assert!(adc > 0.0, "ADC joules must be live");
    for level in ["cell_group", "acc_rf", "weight_sram", "act_sram", "dram"] {
        let v =
            doc.value("osa_energy_joules_total", &[("component", "movement"), ("level", level)]);
        assert_eq!(v, Some(0.0), "compact movement must export as zero at {level}");
    }
    let t = doc
        .value("osa_energy_joules_total", &[("component", "transfer"), ("level", "interconnect")]);
    assert_eq!(t, Some(0.0), "single-macro transfer is zero");
    let per = doc.value("osa_energy_per_inference_joules", &[]).unwrap();
    assert!(per > 0.0, "per-inference gauge must be live:\n{text}");
    gw.shutdown();
}
