//! End-to-end gateway tests over a real socket: HTTP surface, QoS
//! tier latency ordering, governor pressure/drain dynamics and 429
//! backpressure.  Everything runs on `QGraph::synthetic()` — no
//! artifacts needed.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::io::json::{parse, JsonValue};
use osa_hcim::nn::QGraph;
use osa_hcim::serve::http;
use osa_hcim::serve::{Gateway, Tier};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn synth_image(seed: u64) -> Vec<u8> {
    let mut g = osa_hcim::util::prng::SplitMix64::new(seed);
    (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
}

fn infer_body(tier: &str, seed: u64) -> String {
    http::infer_body(tier, &synth_image(seed))
}

fn start_gateway(cfg: &SystemConfig) -> (Gateway, String) {
    let gw = Gateway::start(cfg, Arc::new(QGraph::synthetic()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();
    (gw, addr)
}

fn get_metrics(addr: &str) -> JsonValue {
    let (status, body) = http::request(addr, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200, "metrics endpoint failed: {body}");
    parse(&body).unwrap()
}

fn gov_level(metrics: &JsonValue, tier: &str) -> i64 {
    metrics
        .get("governor")
        .and_then(|g| g.get("tiers"))
        .and_then(|t| t.get(tier))
        .and_then(|t| t.get("level"))
        .and_then(JsonValue::as_i64)
        .expect("governor level in /metrics")
}

#[test]
fn http_surface_health_metrics_infer_and_errors() {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    cfg.max_batch = 4;
    cfg.batch_timeout_us = 500;
    let (gw, addr) = start_gateway(&cfg);

    let (status, body) = http::request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("ok"), "{body}");

    // a good inference round trip
    let (status, body) =
        http::request(&addr, "POST", "/v1/infer", Some(&infer_body("gold", 1))).unwrap();
    assert_eq!(status, 200, "infer failed: {body}");
    let doc = parse(&body).unwrap();
    assert_eq!(doc.get("tier").and_then(JsonValue::as_str), Some("gold"));
    assert_eq!(doc.get("logits").and_then(JsonValue::as_array).map(|a| a.len()), Some(10));
    let pred = doc.get("pred").and_then(JsonValue::as_usize).unwrap();
    assert!(pred < 10);
    assert!(doc.get("latency_us").and_then(JsonValue::as_f64).unwrap() > 0.0);

    // malformed inputs are 4xx, not hangs or 500s
    let (status, _) = http::request(&addr, "POST", "/v1/infer", Some("not json")).unwrap();
    assert_eq!(status, 400);
    let (status, _) =
        http::request(&addr, "POST", "/v1/infer", Some("{\"tier\":\"bronze\",\"image\":[]}"))
            .unwrap();
    assert_eq!(status, 400);
    let (status, _) =
        http::request(&addr, "POST", "/v1/infer", Some("{\"image\":[1,2,3]}")).unwrap();
    assert_eq!(status, 400);
    // present-but-non-string tier is rejected, not silently downgraded
    let (status, _) =
        http::request(&addr, "POST", "/v1/infer", Some("{\"tier\":1,\"image\":[]}")).unwrap();
    assert_eq!(status, 400);
    let (status, _) = http::request(&addr, "GET", "/no/such/route", None).unwrap();
    assert_eq!(status, 404);

    // metrics reflect exactly the one served request
    let m = get_metrics(&addr);
    assert_eq!(m.get("requests").and_then(JsonValue::as_i64), Some(1));
    assert_eq!(
        m.get("tiers").and_then(|t| t.get("gold")).and_then(|t| t.get("requests")).and_then(JsonValue::as_i64),
        Some(1)
    );
    let metrics = gw.shutdown();
    assert_eq!(metrics.requests, 1);
    assert_eq!(metrics.errors, 0);
}

/// Acceptance (a): under mixed-tier burst load, gold's tail latency
/// beats batch's — priority drain + the 8x shorter coalescing window.
#[test]
fn gold_p99_beats_batch_p99_under_burst() {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 2;
    cfg.max_batch = 8;
    cfg.queue_cap = 256;
    cfg.batch_timeout_us = 60_000; // batch coalesces up to 60ms, gold 7.5ms
    let (gw, addr) = start_gateway(&cfg);

    let failures: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let mut clients = Vec::new();
    // 3 batch-tier producers + 2 gold-tier producers, closed loop
    for (t, tier, reqs) in
        [(0, "batch", 6), (1, "batch", 6), (2, "batch", 6), (3, "gold", 6), (4, "gold", 6)]
    {
        let addr = addr.clone();
        let failures = failures.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..reqs {
                let body = infer_body(tier, (t * 100 + i) as u64);
                match http::request(&addr, "POST", "/v1/infer", Some(&body)) {
                    Ok((200, _)) => {}
                    Ok((status, b)) => {
                        failures.lock().unwrap().push(format!("{tier}: status {status}: {b}"))
                    }
                    Err(e) => failures.lock().unwrap().push(format!("{tier}: {e:#}")),
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let fails = failures.lock().unwrap();
    assert!(fails.is_empty(), "{fails:?}");
    drop(fails);

    let metrics = gw.shutdown();
    let gold = metrics.tier(Tier::Gold);
    let batch = metrics.tier(Tier::Batch);
    assert_eq!(gold.requests, 12);
    assert_eq!(batch.requests, 18);
    assert!(
        gold.p99_latency_us() < batch.p99_latency_us(),
        "gold p99 {:.0}us must beat batch p99 {:.0}us",
        gold.p99_latency_us(),
        batch.p99_latency_us()
    );
}

/// Acceptance (b): sustained batch-tier pressure makes the governor
/// degrade the batch tier's precision contract (coarser boundary =
/// higher effective thresholds), and draining restores it — all
/// visible through `/metrics`.
#[test]
fn governor_degrades_batch_under_pressure_and_restores_after_drain() {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Osa; // tier precision only exists on the OSA datapath
    cfg.workers = 1;
    cfg.max_batch = 4;
    cfg.queue_cap = 8;
    cfg.batch_timeout_us = 2_000;
    cfg.gov_high_watermark = 0.2;
    cfg.gov_low_watermark = 0.05;
    cfg.gov_hold_ms = 10;
    let (gw, addr) = start_gateway(&cfg);

    // baseline: batch contract at level 0
    let m0 = get_metrics(&addr);
    assert_eq!(gov_level(&m0, "batch"), 0);
    assert_eq!(gov_level(&m0, "gold"), 0);

    // flood the batch tier from 4 closed-loop clients; tolerate 429
    let stop_poll = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let poller = {
        let addr = addr.clone();
        let stop = stop_poll.clone();
        std::thread::spawn(move || {
            let mut max_level = 0i64;
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                let m = get_metrics(&addr);
                max_level = max_level.max(gov_level(&m, "batch"));
                assert_eq!(gov_level(&m, "gold"), 0, "gold must never degrade");
                std::thread::sleep(Duration::from_millis(15));
            }
            max_level
        })
    };
    let mut clients = Vec::new();
    for t in 0..6u64 {
        let addr = addr.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..4u64 {
                let body = infer_body("batch", t * 1000 + i);
                let _ = http::request(&addr, "POST", "/v1/infer", Some(&body));
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    stop_poll.store(true, std::sync::atomic::Ordering::SeqCst);
    let max_level_seen = poller.join().unwrap();
    // a couple of gold requests so both boundary histograms have mass
    for i in 0..2u64 {
        let (status, body) =
            http::request(&addr, "POST", "/v1/infer", Some(&infer_body("gold", 9000 + i)))
                .unwrap();
        assert_eq!(status, 200, "{body}");
    }
    assert!(
        max_level_seen >= 1,
        "governor never degraded the batch tier under sustained pressure"
    );

    // after the flood drains, idle observations walk the level back to 0
    let deadline = Instant::now() + Duration::from_secs(20);
    let restored = loop {
        let m = get_metrics(&addr);
        if gov_level(&m, "batch") == 0 {
            break true;
        }
        if Instant::now() > deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(restored, "governor did not restore the batch contract after drain");

    let metrics = gw.shutdown();
    let gold = metrics.tier(Tier::Gold);
    let batch = metrics.tier(Tier::Batch);
    assert!(batch.b_hist.iter().sum::<u64>() > 0, "batch boundary histogram is empty");
    assert!(gold.b_hist.iter().sum::<u64>() > 0, "gold boundary histogram is empty");
    // batch served coarser (more analog, higher B) than gold on average:
    // the loose profile + degrade levels push its boundary mass up
    assert!(
        batch.mean_boundary() >= gold.mean_boundary(),
        "batch mean B {:.2} should be at least gold's {:.2}",
        batch.mean_boundary(),
        gold.mean_boundary()
    );
}

/// Acceptance (c): overload answers `429 Too Many Requests` — every
/// request gets an HTTP response (no dropped channels), admitted ones
/// are served.
#[test]
fn overload_returns_429_and_drops_nothing() {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    cfg.max_batch = 1; // serialize the worker so the queue really fills
    cfg.queue_cap = 2;
    cfg.batch_timeout_us = 100;
    let (gw, addr) = start_gateway(&cfg);

    let outcomes: Arc<Mutex<Vec<(u16, String)>>> = Arc::new(Mutex::new(Vec::new()));
    let mut clients = Vec::new();
    for t in 0..12u64 {
        let addr = addr.clone();
        let outcomes = outcomes.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..3u64 {
                let body = infer_body("silver", t * 100 + i);
                let res = http::request(&addr, "POST", "/v1/infer", Some(&body))
                    .expect("every request must get an HTTP response");
                outcomes.lock().unwrap().push(res);
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let outcomes = outcomes.lock().unwrap();
    assert_eq!(outcomes.len(), 36, "a request vanished without a response");
    let mut served = 0u64;
    let mut busy = 0u64;
    for (status, body) in outcomes.iter() {
        match *status {
            200 => {
                let doc = parse(body).unwrap();
                assert_eq!(
                    doc.get("logits").and_then(JsonValue::as_array).map(|a| a.len()),
                    Some(10),
                    "served response is malformed: {body}"
                );
                served += 1;
            }
            429 => {
                assert!(body.contains("busy"), "{body}");
                busy += 1;
            }
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    assert!(served >= 1, "overload starved every request");
    assert!(busy >= 1, "36 rapid requests against cap=2 never saw backpressure");

    let metrics = gw.shutdown();
    assert_eq!(metrics.requests, served, "served count disagrees with metrics");
    assert_eq!(metrics.rejected, busy, "rejected count disagrees with metrics");
    assert_eq!(metrics.errors, 0, "overload must shed, not fail forwards");
}
