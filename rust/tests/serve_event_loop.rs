//! Event-loop gateway tests: incremental parsing at adversarial split
//! points (bit-identical to the whole-buffer path), byte-by-byte
//! request trickling, the slowloris whole-request deadline surviving
//! requests split across many readiness wakeups, pipelined requests
//! arriving in one write, `/metrics` event-loop gauges, and the
//! `event_loop = false` threaded fallback answering byte-for-byte the
//! same on cold paths.  Everything runs on `QGraph::synthetic()`.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::io::json::{parse, JsonValue};
use osa_hcim::nn::QGraph;
use osa_hcim::serve::http::{self, Client};
use osa_hcim::serve::Gateway;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn synth_image(seed: u64) -> Vec<u8> {
    let mut g = osa_hcim::util::prng::SplitMix64::new(seed);
    (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
}

fn dcim_config() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim; // deterministic logits: bit-identity is testable
    cfg.workers = 1;
    cfg.max_batch = 4;
    cfg.batch_timeout_us = 500;
    cfg
}

fn start_gateway(cfg: &SystemConfig) -> (Gateway, String) {
    let gw = Gateway::start(cfg, Arc::new(QGraph::synthetic()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();
    (gw, addr)
}

/// Deterministic part of an infer response (id / latency_us differ).
fn pred_and_logits(body: &str) -> (usize, Vec<u64>) {
    let doc = parse(body).unwrap();
    let pred = doc.get("pred").and_then(JsonValue::as_usize).unwrap();
    let logits: Vec<u64> = doc
        .get("logits")
        .and_then(JsonValue::as_array)
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap().to_bits())
        .collect();
    (pred, logits)
}

fn raw_post(addr: &str, path: &str, body: &str) -> Vec<u8> {
    format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\
         Content-Type: application/json\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Send `req` over a fresh connection in fragments cut at `splits`
/// (byte offsets, ascending), pausing between fragments so each one
/// arrives in its own readiness wakeup, then read the full response.
fn send_in_fragments(addr: &str, req: &[u8], splits: &[usize]) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    let mut at = 0usize;
    for &cut in splits.iter().chain(std::iter::once(&req.len())) {
        assert!(cut >= at && cut <= req.len(), "bad split point {cut}");
        if cut > at {
            s.write_all(&req[at..cut]).unwrap();
            s.flush().unwrap();
            at = cut;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("no status line in: {raw}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

/// Acceptance: a request chopped at adversarial byte offsets (inside
/// the request line, inside a header name, between CR and LF, at the
/// header/body boundary, mid-body) parses to the same response as the
/// whole-buffer path, bit for bit.
#[test]
fn adversarial_split_points_bit_identical() {
    let (gw, addr) = start_gateway(&dcim_config());
    let body = http::infer_body("gold", &synth_image(77));
    let (status, base) = http::request(&addr, "POST", "/v1/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{base}");
    let baseline = pred_and_logits(&base);

    let req = raw_post(&addr, "/v1/infer", &body);
    let head_end = req.windows(4).position(|w| w == b"\r\n\r\n").unwrap();
    let first_crlf = req.windows(2).position(|w| w == b"\r\n").unwrap();
    let split_sets: [Vec<usize>; 7] = [
        vec![2],                             // inside "POST"
        vec![first_crlf + 1],                // between CR and LF of the request line
        vec![first_crlf + 4],                // inside the Host header name
        vec![head_end + 2],                  // middle of the blank line
        vec![head_end + 4],                  // exactly at the header/body boundary
        vec![head_end + 4 + body.len() / 2], // mid-body
        vec![2, first_crlf + 1, head_end + 2, head_end + 4, req.len() - 1], // all at once
    ];
    for splits in &split_sets {
        let (status, resp) = send_in_fragments(&addr, &req, splits);
        assert_eq!(status, 200, "splits {splits:?}: {resp}");
        assert_eq!(
            pred_and_logits(&resp),
            baseline,
            "response differs from the whole-buffer path at splits {splits:?}"
        );
    }
    let metrics = gw.shutdown();
    assert_eq!(metrics.errors, 0);
}

/// A small request trickled one byte per write still parses and the
/// connection stays usable for a follow-up request.
#[test]
fn byte_by_byte_request_parses() {
    let (gw, addr) = start_gateway(&dcim_config());
    let req = format!("GET /healthz HTTP/1.1\r\nHost: {addr}\r\n\r\n");
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_nodelay(true).unwrap();
    for b in req.as_bytes() {
        s.write_all(std::slice::from_ref(b)).unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut buf = [0u8; 4096];
    let n = s.read(&mut buf).unwrap();
    let raw = String::from_utf8_lossy(&buf[..n]);
    assert!(raw.contains("200 OK"), "{raw}");
    assert!(raw.contains("\"ok\""), "{raw}");
    drop(s);

    // framing violations still answer 400 when trickled byte-by-byte
    let bad = format!("POST /v1/infer HTTP/1.1\r\nHost: {addr}\r\nContent-Length: +3\r\n\r\nabc");
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_nodelay(true).unwrap();
    for b in bad.as_bytes() {
        if s.write_all(std::slice::from_ref(b)).is_err() {
            break; // server may 400 + close before the body arrives
        }
        let _ = s.flush();
        std::thread::sleep(Duration::from_millis(1));
    }
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut raw = String::new();
    let _ = s.read_to_string(&mut raw);
    assert!(raw.contains("400 Bad Request"), "{raw}");
    assert!(raw.contains("Content-Length"), "{raw}");
    gw.shutdown();
}

/// Two complete requests arriving in a single write are both served,
/// in order, on the one connection.
#[test]
fn pipelined_requests_in_one_write() {
    let (gw, addr) = start_gateway(&dcim_config());
    let one = format!("GET /healthz HTTP/1.1\r\nHost: {addr}\r\n\r\n");
    let two = format!("GET /v1/version HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(format!("{one}{two}").as_bytes()).unwrap();
    s.flush().unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    assert_eq!(raw.matches("HTTP/1.1 200 OK").count(), 2, "{raw}");
    assert!(raw.contains("\"ok\""), "{raw}");
    assert!(raw.contains("\"api\""), "{raw}");
    let health = raw.find("\"ok\"").unwrap();
    let version = raw.find("\"api\"").unwrap();
    assert!(health < version, "responses out of order: {raw}");
    let metrics = gw.shutdown();
    assert_eq!(metrics.errors, 0);
}

/// The slowloris guard survives requests split across many readiness
/// wakeups: a peer feeding one byte at a time fast enough to defeat
/// the per-read timeout still hits the whole-request deadline
/// (anchored at the FIRST byte of the request) and gets a 408.
#[cfg(unix)]
#[test]
fn slowloris_across_wakeups_gets_408() {
    let mut cfg = dcim_config();
    cfg.read_timeout_ms = 150; // whole-request deadline = 4x = 600ms
    let (gw, addr) = start_gateway(&cfg);

    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(Duration::from_millis(1))).unwrap();
    let drip = format!("POST /v1/infer HTTP/1.1\r\nHost: {addr}\r\nX-Pad: {}", "a".repeat(512));
    let t0 = Instant::now();
    let mut got = Vec::new();
    let mut buf = [0u8; 1024];
    // each byte lands well inside the 150ms per-read timeout, so only
    // the first-byte-anchored whole-request deadline can stop this
    'drip: for b in drip.as_bytes() {
        if s.write_all(std::slice::from_ref(b)).is_err() {
            break; // server gave up on us — expected
        }
        let _ = s.flush();
        std::thread::sleep(Duration::from_millis(40));
        match s.read(&mut buf) {
            Ok(0) => break 'drip, // closed without a byte: the 408 is already drained below
            Ok(n) => {
                got.extend_from_slice(&buf[..n]);
                break 'drip; // the 408 landed
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => break 'drip,
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "slowloris peer never shed");
    }
    let shed_at = t0.elapsed();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let _ = s.read_to_end(&mut got);
    let raw = String::from_utf8_lossy(&got);
    assert!(raw.contains("408"), "slowloris peer answer: {raw}");
    assert!(raw.contains("stalled"), "{raw}");
    assert!(
        shed_at >= Duration::from_millis(400),
        "shed too early ({shed_at:?}) — per-read timeout fired instead of the request deadline"
    );
    gw.shutdown();
}

/// `/metrics` exposes the event-loop gauges: open connections, epoll
/// wakeups, EAGAIN counts, deadline expirations and the buffer-pool
/// hit rate.
#[cfg(unix)]
#[test]
fn metrics_expose_event_loop_gauges() {
    let (gw, addr) = start_gateway(&dcim_config());
    // a few keep-alive requests so wakeups and pool reuse accumulate
    let mut c = Client::connect(&addr).unwrap();
    for seed in [1u64, 2] {
        let body = http::infer_body("gold", &synth_image(seed));
        let (status, resp) = c.request("POST", "/v1/infer", Some(&body)).unwrap();
        assert_eq!(status, 200, "{resp}");
    }
    // a same-wakeup request/response cycle always drains the socket to
    // EAGAIN before /metrics below samples the gauges
    let (status, _) = c.request("GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let (status, body) = c.request("GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let m = parse(&body).unwrap();
    let ev = m.get("event_loop").expect("event_loop block in /metrics");
    let gauge = |k: &str| {
        ev.get(k)
            .and_then(JsonValue::as_f64)
            .unwrap_or_else(|| panic!("missing event_loop.{k}: {body}"))
    };
    assert!(gauge("open_connections") >= 1.0, "our own connection is open");
    assert!(gauge("wakeups") >= 3.0, "three requests = at least three wakeups");
    assert!(gauge("eagain_reads") >= 1.0, "level-triggered reads must drain to EAGAIN");
    assert!(gauge("parked_connections") >= 0.0);
    assert!(gauge("deadline_expirations") >= 0.0);
    let hit_rate = gauge("buffer_pool_hit_rate");
    assert!((0.0..=1.0).contains(&hit_rate), "pool hit rate out of range: {hit_rate}");
    let metrics = gw.shutdown();
    assert_eq!(metrics.errors, 0);
}

/// `event_loop = false` falls back to the threaded gateway, and the
/// two modes answer cold paths byte-for-byte identically (shared
/// routing/rendering layer) and infer requests bit-identically.
#[test]
fn threaded_fallback_is_byte_equivalent() {
    let mut threaded_cfg = dcim_config();
    threaded_cfg.event_loop = false;
    let (gw_t, addr_t) = start_gateway(&threaded_cfg);
    let (gw_e, addr_e) = start_gateway(&dcim_config());

    // deterministic cold paths: raw bytes must match exactly
    for req in [
        "GET /nope HTTP/1.1\r\nHost: h\r\nConnection: close\r\n\r\n".to_string(),
        "PUT /v1/infer HTTP/1.1\r\nHost: h\r\nConnection: close\r\n\r\n".to_string(),
        "POST /v1/infer HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 0\r\n\r\nabc"
            .to_string(),
    ] {
        let fetch = |addr: &str| {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(req.as_bytes()).unwrap();
            s.flush().unwrap();
            s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
            let mut raw = Vec::new();
            s.read_to_end(&mut raw).unwrap();
            raw
        };
        let a = fetch(&addr_t);
        let b = fetch(&addr_e);
        assert_eq!(
            String::from_utf8_lossy(&a),
            String::from_utf8_lossy(&b),
            "threaded and event-loop responses differ for: {req}"
        );
    }

    // inference answers are bit-identical across modes
    let body = http::infer_body("gold", &synth_image(9));
    let (st_t, resp_t) = http::request(&addr_t, "POST", "/v1/infer", Some(&body)).unwrap();
    let (st_e, resp_e) = http::request(&addr_e, "POST", "/v1/infer", Some(&body)).unwrap();
    assert_eq!((st_t, st_e), (200, 200), "{resp_t} / {resp_e}");
    assert_eq!(pred_and_logits(&resp_t), pred_and_logits(&resp_e));

    gw_t.shutdown();
    gw_e.shutdown();
}
