//! End-to-end tests for the persistent-connection engine: pipelined
//! sequential requests on one socket (bit-identical to the
//! one-connection-per-request path), NDJSON batch inference, malformed
//! mid-stream requests, read-timeout shedding, connection-limit 429s
//! and drain-on-shutdown.  Everything runs on `QGraph::synthetic()` —
//! no artifacts needed.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::io::json::{parse, JsonValue};
use osa_hcim::nn::QGraph;
use osa_hcim::serve::http::{self, Client};
use osa_hcim::serve::{Gateway, Tier};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn synth_image(seed: u64) -> Vec<u8> {
    let mut g = osa_hcim::util::prng::SplitMix64::new(seed);
    (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
}

fn infer_body(tier: &str, seed: u64) -> String {
    http::infer_body(tier, &synth_image(seed))
}

fn dcim_config() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim; // deterministic logits: bit-identity is testable
    cfg.workers = 1;
    cfg.max_batch = 4;
    cfg.batch_timeout_us = 500;
    cfg
}

fn start_gateway(cfg: &SystemConfig) -> (Gateway, String) {
    let gw = Gateway::start(cfg, Arc::new(QGraph::synthetic()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();
    (gw, addr)
}

/// Extract (pred, logits-bits) — the deterministic part of an infer
/// response (id / latency_us legitimately differ between runs).
fn pred_and_logits(body: &str) -> (usize, Vec<u64>) {
    let doc = parse(body).unwrap();
    let pred = doc.get("pred").and_then(JsonValue::as_usize).unwrap();
    let logits: Vec<u64> = doc
        .get("logits")
        .and_then(JsonValue::as_array)
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap().to_bits())
        .collect();
    (pred, logits)
}

/// Acceptance: >= 2 requests served over ONE TCP connection, each
/// bit-identical to the same request over its own connection.
#[test]
fn keepalive_serves_pipelined_requests_bit_identical() {
    let (gw, addr) = start_gateway(&dcim_config());

    // baseline: one connection per request (Connection: close)
    let mut baseline = Vec::new();
    for seed in [11u64, 22, 33] {
        let (status, body) =
            http::request(&addr, "POST", "/v1/infer", Some(&infer_body("gold", seed))).unwrap();
        assert_eq!(status, 200, "{body}");
        baseline.push(pred_and_logits(&body));
    }

    // the same three requests over one persistent connection
    let mut c = Client::connect(&addr).unwrap();
    for (i, seed) in [11u64, 22, 33].iter().enumerate() {
        let (status, body) =
            c.request("POST", "/v1/infer", Some(&infer_body("gold", *seed))).unwrap();
        assert_eq!(status, 200, "{body}");
        assert!(!c.is_closed(), "server closed a keep-alive session early");
        assert_eq!(
            pred_and_logits(&body),
            baseline[i],
            "request {i} differs between keep-alive and per-connection serving"
        );
    }

    // the reuse is visible in /metrics: fewer connections than requests
    let (status, body) = c.request("GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let m = parse(&body).unwrap();
    let conns = m.get("connections").expect("connections block in /metrics");
    let accepted = conns.get("accepted").and_then(JsonValue::as_i64).unwrap();
    let requests = conns.get("http_requests").and_then(JsonValue::as_i64).unwrap();
    assert!(requests >= accepted + 3, "no connection reuse: {accepted} conns / {requests} reqs");
    assert!(
        conns.get("reuse_rate").and_then(JsonValue::as_f64).unwrap() > 0.0,
        "reuse_rate not reported"
    );

    let metrics = gw.shutdown();
    assert_eq!(metrics.requests, 6);
    assert_eq!(metrics.errors, 0);
}

/// A malformed request mid-session answers 400 with `Connection:
/// close` and the socket actually closes (no half-dead session).
#[test]
fn malformed_mid_stream_closes_cleanly() {
    let (gw, addr) = start_gateway(&dcim_config());
    let mut c = Client::connect(&addr).unwrap();
    let (status, _) = c.request("POST", "/v1/infer", Some(&infer_body("silver", 1))).unwrap();
    assert_eq!(status, 200);

    // inject a framing violation on the live session: duplicate
    // Content-Length is the request-smuggling shape
    c.stream_mut()
        .write_all(b"POST /v1/infer HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 0\r\n\r\nabc")
        .unwrap();
    c.stream_mut().flush().unwrap();
    c.stream_mut().set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut raw = String::new();
    // read_to_string returning proves the server closed the socket
    c.stream_mut().read_to_string(&mut raw).unwrap();
    assert!(raw.contains("400 Bad Request"), "{raw}");
    assert!(raw.contains("Connection: close"), "{raw}");
    assert!(raw.contains("duplicate"), "{raw}");

    let metrics = gw.shutdown();
    assert_eq!(metrics.errors, 0, "a parse error must never reach the workers");
}

/// Strict Content-Length: a leading '+' (which `usize::parse` accepts)
/// is a 400, not a silently mis-framed body.
#[test]
fn nondigit_content_length_rejected() {
    let (gw, addr) = start_gateway(&dcim_config());
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"POST /v1/infer HTTP/1.1\r\nContent-Length: +3\r\n\r\nabc").unwrap();
    s.flush().unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    assert!(raw.contains("400 Bad Request"), "{raw}");
    assert!(raw.contains("Content-Length"), "{raw}");
    gw.shutdown();
}

/// The read timeout sheds stalled peers: a half-sent request gets a
/// 408 and the socket closes; an idle keep-alive session is closed
/// silently.
#[test]
fn read_timeout_kicks_stalled_peer() {
    let mut cfg = dcim_config();
    cfg.read_timeout_ms = 150;
    let (gw, addr) = start_gateway(&cfg);

    // stalled mid-request: request line sent, then silence
    let mut stalled = TcpStream::connect(&addr).unwrap();
    stalled.write_all(b"POST /v1/infer HTTP/1.1\r\n").unwrap();
    stalled.flush().unwrap();
    stalled.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let t0 = Instant::now();
    let mut raw = String::new();
    stalled.read_to_string(&mut raw).unwrap();
    assert!(raw.contains("408"), "stalled peer answer: {raw}");
    assert!(t0.elapsed() < Duration::from_secs(5), "timeout took {:?}", t0.elapsed());

    // idle at a request boundary: closed silently (clean EOF, no 408)
    let mut idle = TcpStream::connect(&addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut raw = String::new();
    idle.read_to_string(&mut raw).unwrap();
    assert!(raw.is_empty(), "idle close must be silent, got: {raw}");

    // a well-behaved request still works afterwards
    let (status, _) =
        http::request(&addr, "POST", "/v1/infer", Some(&infer_body("gold", 5))).unwrap();
    assert_eq!(status, 200);
    gw.shutdown();
}

/// Graceful drain: a request already inside the coordinator when
/// shutdown starts is answered, not dropped.
#[test]
fn drain_on_shutdown_finishes_in_flight_requests() {
    let mut cfg = dcim_config();
    // a lone batch-tier request coalesces for its full 100ms window —
    // plenty of time for shutdown to start while it is in flight
    cfg.batch_timeout_us = 100_000;
    cfg.max_batch = 8;
    let (gw, addr) = start_gateway(&cfg);

    let client = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            c.request("POST", "/v1/infer", Some(&infer_body("batch", 7))).unwrap()
        })
    };
    // Wait until the POST is demonstrably in flight before shutting
    // down.  `connections.http_requests` increments the moment a
    // request is read off the socket (before dispatch), and each of our
    // /metrics polls adds exactly one more — so the counter exceeding
    // the poll count proves the POST has been read and will therefore
    // be drained, not dropped.
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut polls = 0i64;
    loop {
        polls += 1;
        let (status, body) = http::request(&addr, "GET", "/metrics", None).unwrap();
        assert_eq!(status, 200);
        let m = parse(&body).unwrap();
        let reqs = m
            .get("connections")
            .and_then(|c| c.get("http_requests"))
            .and_then(JsonValue::as_i64)
            .unwrap();
        if reqs > polls {
            break;
        }
        assert!(Instant::now() < deadline, "the POST was never read by the gateway");
        std::thread::sleep(Duration::from_millis(5));
    }
    let metrics = gw.shutdown();
    let (status, body) = client.join().unwrap();
    assert_eq!(status, 200, "in-flight request was dropped by shutdown: {body}");
    let (pred, logits) = pred_and_logits(&body);
    assert!(pred < 10);
    assert_eq!(logits.len(), 10);
    assert_eq!(metrics.requests, 1);
    assert_eq!(metrics.errors, 0);
}

/// NDJSON batch inference: per-line tiers, per-line errors, input
/// order, and bit-identity with the single-request path.
#[test]
fn infer_batch_ndjson_roundtrip() {
    let (gw, addr) = start_gateway(&dcim_config());

    // singles first (fresh connections) for the identity baseline
    let mut baseline = Vec::new();
    for (tier, seed) in [("gold", 100u64), ("silver", 200), ("batch", 300)] {
        let (status, body) =
            http::request(&addr, "POST", "/v1/infer", Some(&infer_body(tier, seed))).unwrap();
        assert_eq!(status, 200, "{body}");
        baseline.push(pred_and_logits(&body));
    }

    // NDJSON: explicit gold, an interior blank line (skipped but the
    // numbering must not shift), tier-less (defaults to silver), a
    // broken line, then batch — all in one request on one connection
    let img_silver = synth_image(200);
    let mut ndjson = String::new();
    ndjson.push_str(&infer_body("gold", 100)); // input line 0
    ndjson.push_str("\n\n"); // input line 1: blank
    ndjson.push_str(&http::infer_body("silver", &img_silver).replace("\"tier\":\"silver\",", ""));
    ndjson.push('\n'); // input line 2
    ndjson.push_str("{\"tier\":\"bronze\",\"image\":[]}\n"); // input line 3
    ndjson.push_str(&infer_body("batch", 300)); // input line 4
    ndjson.push('\n');

    let mut c = Client::connect(&addr).unwrap();
    let (status, body) = c
        .request_typed("POST", "/v1/infer_batch", "application/x-ndjson", Some(&ndjson))
        .unwrap();
    assert_eq!(status, 200, "{body}");
    let lines: Vec<&str> = body.lines().collect();
    assert_eq!(lines.len(), 4, "one NDJSON result per non-blank input line: {body}");

    // (response position, original input line, tier, baseline index)
    let expected = [(0usize, 0usize, "gold", 0usize), (1, 2, "silver", 1), (3, 4, "batch", 2)];
    for (pos, input_line, expect_tier, base_idx) in expected {
        let doc = parse(lines[pos]).unwrap();
        assert_eq!(
            doc.get("line").and_then(JsonValue::as_usize),
            Some(input_line),
            "result numbering must use the client's own line numbers: {}",
            lines[pos]
        );
        assert_eq!(doc.get("tier").and_then(JsonValue::as_str), Some(expect_tier));
        assert_eq!(
            pred_and_logits(lines[pos]),
            baseline[base_idx],
            "batch line {input_line} differs from the single-request path"
        );
    }
    let broken = parse(lines[2]).unwrap();
    assert_eq!(broken.get("line").and_then(JsonValue::as_usize), Some(3));
    assert!(
        broken.get("error").and_then(JsonValue::as_str).unwrap().contains("bronze"),
        "per-line error missing: {}",
        lines[2]
    );

    // an empty body is a request-level 400
    let (status, _) = c
        .request_typed("POST", "/v1/infer_batch", "application/x-ndjson", Some("\n\n"))
        .unwrap();
    assert_eq!(status, 400);

    let metrics = gw.shutdown();
    assert_eq!(metrics.requests, 6, "3 singles + 3 valid batch lines");
    assert_eq!(metrics.tier(Tier::Silver).requests, 2);
    assert_eq!(metrics.errors, 0);
}

/// Connection admission: with the worker pool and backlog full, a new
/// connection is answered 429 and closed; a queued connection is still
/// served once capacity frees up.
#[test]
fn connection_limit_answers_429_then_recovers() {
    let mut cfg = dcim_config();
    cfg.max_conns = 1; // one worker + one backlog slot
    let (gw, addr) = start_gateway(&cfg);

    // hold the lone worker with an idle keep-alive session
    let mut held = Client::connect(&addr).unwrap();
    let (status, _) = held.request("POST", "/v1/infer", Some(&infer_body("gold", 1))).unwrap();
    assert_eq!(status, 200);

    // fills the single backlog slot (request queued but unserved)
    let mut queued = TcpStream::connect(&addr).unwrap();
    let body = infer_body("silver", 2);
    let req = format!(
        "POST /v1/infer HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    queued.write_all(req.as_bytes()).unwrap();
    queued.flush().unwrap();
    std::thread::sleep(Duration::from_millis(100)); // let accept queue it

    // overflow: answered 429 at admission without reading a request
    let mut over = TcpStream::connect(&addr).unwrap();
    over.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut raw = String::new();
    over.read_to_string(&mut raw).unwrap();
    assert!(raw.contains("429"), "overflow connection got: {raw}");
    assert!(raw.contains("busy"), "{raw}");

    // free the worker: the queued connection must now be served
    drop(held);
    queued.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut raw = String::new();
    queued.read_to_string(&mut raw).unwrap();
    assert!(raw.contains("200 OK"), "queued connection starved: {raw}");

    let metrics = gw.shutdown();
    assert_eq!(metrics.requests, 2);
}
