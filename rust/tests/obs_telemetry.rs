//! End-to-end observability tests over a real socket, in both serving
//! modes (thread-per-connection and event loop): X-Request-Id echo and
//! adoption, `/debug/trace` span coverage, Prometheus exposition
//! round-trip through the in-crate parser (the CI exposition lint),
//! and `/metrics` JSON back-compat + NaN-free guarantee.  Everything
//! runs on `QGraph::synthetic()` — no artifacts needed.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::io::json::{parse, JsonValue};
use osa_hcim::nn::QGraph;
use osa_hcim::obs;
use osa_hcim::serve::http;
use osa_hcim::serve::Gateway;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn synth_image(seed: u64) -> Vec<u8> {
    let mut g = osa_hcim::util::prng::SplitMix64::new(seed);
    (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
}

fn infer_body(tier: &str, seed: u64) -> String {
    http::infer_body(tier, &synth_image(seed))
}

fn base_cfg(event_loop: bool) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    cfg.max_batch = 4;
    cfg.batch_timeout_us = 500;
    cfg.event_loop = event_loop;
    cfg
}

fn start_gateway(cfg: &SystemConfig) -> (Gateway, String) {
    let gw = Gateway::start(cfg, Arc::new(QGraph::synthetic()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();
    (gw, addr)
}

/// One-shot request with caller-controlled extra headers; returns
/// (status, lower-cased response headers, body).  The stock clients in
/// `serve::http` don't expose request headers, and the id-propagation
/// tests need to *send* `X-Request-Id`, not just read it back.
fn raw_request(
    addr: &str,
    method: &str,
    path: &str,
    extra_headers: &[(&str, &str)],
    body: Option<&str>,
) -> (u16, BTreeMap<String, String>, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let payload = body.unwrap_or("");
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\n");
    for (k, v) in extra_headers {
        req.push_str(&format!("{k}: {v}\r\n"));
    }
    req.push_str(&format!(
        "Content-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    ));
    req.push_str(payload);
    stream.write_all(req.as_bytes()).unwrap();
    stream.flush().unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let raw = String::from_utf8_lossy(&raw).into_owned();
    let header_end = raw.find("\r\n\r\n").expect("malformed response");
    let mut lines = raw[..header_end].split("\r\n");
    let status_line = lines.next().unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut headers = BTreeMap::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    (status, headers, raw[header_end + 4..].to_string())
}

#[test]
fn request_id_echoed_and_adopted_in_both_modes() {
    for event_loop in [false, true] {
        let (gw, addr) = start_gateway(&base_cfg(event_loop));
        // a well-formed inbound id is adopted and echoed verbatim
        let rid = "req-00000000000000ab";
        let (status, headers, body) = raw_request(
            &addr,
            "POST",
            "/v1/infer",
            &[("X-Request-Id", rid)],
            Some(&infer_body("gold", 1)),
        );
        assert_eq!(status, 200, "event_loop={event_loop}: {body}");
        assert_eq!(
            headers.get("x-request-id").map(String::as_str),
            Some(rid),
            "event_loop={event_loop}"
        );
        // no inbound id: the gateway mints a well-formed one
        let (status, headers, _) =
            raw_request(&addr, "POST", "/v1/infer", &[], Some(&infer_body("gold", 2)));
        assert_eq!(status, 200);
        let minted = headers.get("x-request-id").expect("minted id");
        assert!(obs::parse_rid(minted).is_some(), "{minted}");
        assert_ne!(minted.as_str(), rid);
        // a malformed inbound id is replaced, never parroted back
        let (status, headers, _) = raw_request(
            &addr,
            "POST",
            "/v1/infer",
            &[("X-Request-Id", "not-a-rid")],
            Some(&infer_body("gold", 3)),
        );
        assert_eq!(status, 200);
        let replaced = headers.get("x-request-id").expect("replacement id");
        assert!(obs::parse_rid(replaced).is_some(), "{replaced}");
        gw.shutdown();
    }
}

#[test]
fn debug_trace_spans_cover_the_request_lifecycle() {
    let (gw, addr) = start_gateway(&base_cfg(false));
    let rid = "req-0000000000000042";
    let (status, _, body) = raw_request(
        &addr,
        "POST",
        "/v1/infer",
        &[("X-Request-Id", rid)],
        Some(&infer_body("gold", 7)),
    );
    assert_eq!(status, 200, "{body}");

    // a bad count is a 400, not a panic or a hang
    let (status, body) = http::request(&addr, "GET", "/debug/trace?n=banana", None).unwrap();
    assert_eq!(status, 400, "{body}");

    // spans for this request id, as (category, start_ts) pairs
    let fetch = || -> Vec<(String, f64)> {
        let (status, body) = http::request(&addr, "GET", "/debug/trace?n=1024", None).unwrap();
        assert_eq!(status, 200, "{body}");
        let doc = parse(&body).unwrap();
        let events = doc.get("traceEvents").and_then(JsonValue::as_array).unwrap();
        events
            .iter()
            .filter(|e| {
                let id = e.get("args").and_then(|a| a.get("request_id"));
                id.and_then(JsonValue::as_str) == Some(rid)
            })
            .map(|e| {
                let cat = e.get("cat").and_then(JsonValue::as_str).unwrap();
                (cat.to_string(), e.get("ts").and_then(JsonValue::as_f64).unwrap())
            })
            .collect()
    };
    // the write span lands just after the response bytes reach the
    // client, so poll briefly instead of racing it
    let needed = ["parse", "admit", "queue", "exec", "write"];
    let deadline = Instant::now() + Duration::from_secs(10);
    let got = loop {
        let cur = fetch();
        if needed.iter().all(|n| cur.iter().any(|(c, _)| c == n)) {
            break cur;
        }
        assert!(Instant::now() < deadline, "stages still missing after 10s: {cur:?}");
        std::thread::sleep(Duration::from_millis(20));
    };
    // per-layer exec sub-spans ride on the same request id
    assert!(got.iter().any(|(c, _)| c == "layer"), "no layer spans: {got:?}");
    // lifecycle ordering by span start time
    let ts_of = |name: &str| got.iter().find(|(c, _)| c == name).unwrap().1;
    assert!(ts_of("parse") <= ts_of("admit"), "{got:?}");
    assert!(ts_of("admit") <= ts_of("queue"), "{got:?}");
    assert!(ts_of("queue") <= ts_of("exec"), "{got:?}");
    assert!(ts_of("exec") <= ts_of("write"), "{got:?}");
    gw.shutdown();
}

/// The CI exposition-syntax lint: scrape a live gateway and push the
/// text back through the in-crate parser, which enforces name syntax,
/// family contiguity, histogram cumulativity and value well-formedness.
#[test]
fn prometheus_exposition_round_trips_from_a_live_gateway() {
    let (gw, addr) = start_gateway(&base_cfg(false));
    for i in 0..2u64 {
        let (status, body) =
            http::request(&addr, "POST", "/v1/infer", Some(&infer_body("gold", 10 + i))).unwrap();
        assert_eq!(status, 200, "{body}");
    }
    let (status, headers, text) =
        raw_request(&addr, "GET", "/metrics?format=prometheus", &[], None);
    assert_eq!(status, 200);
    assert!(
        headers.get("content-type").is_some_and(|c| c.starts_with("text/plain; version=0.0.4")),
        "{headers:?}"
    );
    let doc = match obs::parse_exposition(&text) {
        Ok(d) => d,
        Err(e) => panic!("exposition must parse: {e}\n{text}"),
    };
    assert_eq!(doc.value("osa_requests_total", &[]), Some(2.0));
    assert_eq!(doc.value("osa_tier_requests_total", &[("tier", "gold")]), Some(2.0));
    let ty = doc.types.get("osa_request_latency_microseconds");
    assert_eq!(ty.map(String::as_str), Some("histogram"));
    assert_eq!(doc.value("osa_request_latency_microseconds_count", &[]), Some(2.0));
    let stage_exec = [("tier", "gold"), ("stage", "exec")];
    assert_eq!(doc.value("osa_stage_duration_microseconds_count", &stage_exec), Some(2.0));
    assert_eq!(doc.value("osa_governor_level", &[("tier", "gold")]), Some(0.0));
    // Accept negotiation picks the exposition; the bare default stays
    // JSON so pre-existing scrapers see no change
    let (_, _, via_accept) =
        raw_request(&addr, "GET", "/metrics", &[("Accept", "text/plain")], None);
    assert!(via_accept.starts_with("# HELP"), "{via_accept}");
    let (_, plain) = http::request(&addr, "GET", "/metrics", None).unwrap();
    assert!(plain.trim_start().starts_with('{'), "bare /metrics must stay JSON");
    gw.shutdown();
}

/// Every number anywhere in the `/metrics` JSON document must be
/// finite: `fnum` scrubs at the emit sites, and this walk catches any
/// future field that bypasses it.
fn assert_finite(v: &JsonValue, path: &str) {
    match v {
        JsonValue::Number(x) => assert!(x.is_finite(), "non-finite number at {path}"),
        JsonValue::Array(items) => {
            for (i, item) in items.iter().enumerate() {
                assert_finite(item, &format!("{path}[{i}]"));
            }
        }
        JsonValue::Object(map) => {
            for (k, item) in map {
                assert_finite(item, &format!("{path}.{k}"));
            }
        }
        _ => {}
    }
}

#[test]
fn json_metrics_keeps_every_preexisting_key_in_both_modes() {
    for event_loop in [false, true] {
        let (gw, addr) = start_gateway(&base_cfg(event_loop));
        let (status, body) =
            http::request(&addr, "POST", "/v1/infer", Some(&infer_body("silver", 5))).unwrap();
        assert_eq!(status, 200, "event_loop={event_loop}: {body}");
        let (status, body) = http::request(&addr, "GET", "/metrics", None).unwrap();
        assert_eq!(status, 200);
        let m = parse(&body).unwrap();
        // the pre-PR-7 top-level contract, key for key
        for key in [
            "requests",
            "batches",
            "errors",
            "rejected",
            "mean_batch",
            "p50_latency_us",
            "p95_latency_us",
            "p99_latency_us",
            "throughput_rps",
            "tops_per_watt",
            "watts",
            "b_hist",
            "tiers",
            "governor",
            "connections",
        ] {
            assert!(m.get(key).is_some(), "event_loop={event_loop}: missing key {key}");
        }
        for tier in ["gold", "silver", "batch"] {
            let t = m.get("tiers").and_then(|t| t.get(tier)).expect("tier object");
            for key in [
                "requests",
                "errors",
                "rejected",
                "queue_depth",
                "p50_latency_us",
                "p99_latency_us",
                "mean_boundary",
                "b_hist",
            ] {
                assert!(t.get(key).is_some(), "tier {tier} missing {key}");
            }
            // the PR-7 stage breakdown rides along
            for key in ["p50_queue_us", "p99_exec_us", "p50_write_us"] {
                assert!(t.get(key).is_some(), "tier {tier} missing {key}");
            }
        }
        let gov = m.get("governor").expect("governor block");
        assert!(gov.get("enabled").is_some() && gov.get("transitions").is_some());
        assert!(gov.get("tiers").and_then(|t| t.get("gold")).is_some());
        // PR-7 additions
        assert!(m.get("layers").is_some(), "layer attribution missing");
        let o = m.get("obs").expect("obs block");
        for key in ["trace_enabled", "trace_capacity", "spans_recorded", "spans_dropped"] {
            assert!(o.get(key).is_some(), "obs block missing {key}");
        }
        if event_loop {
            assert!(m.get("event_loop").is_some(), "event-loop gauges missing");
        }
        assert_finite(&m, "$");
        gw.shutdown();
    }
}
