//! End-to-end integration over the quantized CNN engine + macro
//! datapath (requires `make artifacts`; skips when absent).

use osa_hcim::config::CimMode;
use osa_hcim::nn::data::{Dataset, Golden};
use osa_hcim::nn::{accuracy, cross_entropy, Executor, QGraph};
use osa_hcim::sched::MacroGemm;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = osa_hcim::spec::default_artifacts_dir();
    dir.join("spec.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

#[test]
fn native_dcim_reproduces_python_quantized_golden() {
    let dir = require_artifacts!();
    let graph = QGraph::load(&dir).unwrap();
    let ds = Dataset::load(&dir).unwrap();
    let golden = Golden::load(&dir).unwrap();
    let n = golden.golden_n;
    let (imgs, _) = ds.test_batch(0, n);
    let mut exec = Executor::new(&graph, MacroGemm::with_mode(CimMode::Dcim));
    let (logits, stats) = exec.forward(imgs, n).unwrap();
    // DCIM is exact integer math on both sides; the float steps (dequant
    // scales, GAP mean, requantize) can land exactly on a rounding
    // boundary, so allow one FC-input quantization step of slack.
    for (i, (a, b)) in logits.iter().zip(&golden.dcim_logits).enumerate() {
        assert!(
            (a - b).abs() <= 1.5e-2 * b.abs().max(1.0),
            "logit {i}: native {a} vs golden {b}"
        );
    }
    assert!(stats.account.macro_ops > 0);
    assert_eq!(stats.b_hist[0], stats.account.macro_ops);
}

#[test]
fn mode_accuracy_ordering_holds() {
    // The paper's Fig 9 ordering: DCIM >= HCIM(B=8) >> coarse points;
    // every mode must stay well above chance except possibly ACIM.
    let dir = require_artifacts!();
    let graph = QGraph::load(&dir).unwrap();
    let ds = Dataset::load(&dir).unwrap();
    let n = 48usize.min(ds.test_n());
    let (imgs, labels) = ds.test_batch(0, n);
    let mut accs = std::collections::BTreeMap::new();
    for (name, mode, b) in [
        ("dcim", CimMode::Dcim, 0),
        ("hcim6", CimMode::Hcim, 6),
        ("hcim8", CimMode::Hcim, 8),
    ] {
        let mut gemm = MacroGemm::with_mode(mode);
        gemm.fixed_b = b;
        let mut exec = Executor::new(&graph, gemm);
        let (logits, _) = exec.forward(imgs, n).unwrap();
        accs.insert(name, accuracy(&logits, labels, graph.num_classes));
    }
    assert!(accs["dcim"] > 0.9, "DCIM too weak: {:?}", accs);
    assert!(accs["dcim"] >= accs["hcim8"] - 1e-9, "{accs:?}");
    assert!(accs["hcim6"] >= accs["hcim8"] - 0.05, "{accs:?}");
    assert!(accs["hcim8"] > 0.85, "hybrid B=8 collapsed: {accs:?}");
}

#[test]
fn energy_ordering_matches_paper_claims() {
    let dir = require_artifacts!();
    let graph = QGraph::load(&dir).unwrap();
    let ds = Dataset::load(&dir).unwrap();
    let n = 16usize.min(ds.test_n());
    let (imgs, _) = ds.test_batch(0, n);
    let mut energy = std::collections::BTreeMap::new();
    for (name, mode, b) in [
        ("dcim", CimMode::Dcim, 0),
        ("hcim8", CimMode::Hcim, 8),
        ("osa", CimMode::Osa, 8),
    ] {
        let mut gemm = MacroGemm::with_mode(mode);
        gemm.fixed_b = b;
        let mut exec = Executor::new(&graph, gemm);
        let (_, stats) = exec.forward(imgs, n).unwrap();
        energy.insert(name, stats.account.total_energy_j());
    }
    let r_hcim = energy["dcim"] / energy["hcim8"];
    assert!(
        (1.4..1.8).contains(&r_hcim),
        "HCIM ratio {r_hcim:.3}, paper says 1.56x"
    );
    assert!(energy["osa"] < energy["dcim"], "OSA must beat DCIM energy");
}

#[test]
fn osa_bda_maps_have_spatial_structure() {
    // Fig 8a property: boundary maps must not be constant — the OSE must
    // separate salient from non-salient positions within an image.
    let dir = require_artifacts!();
    let graph = QGraph::load(&dir).unwrap();
    let ds = Dataset::load(&dir).unwrap();
    let mut gemm = MacroGemm::with_mode(CimMode::Osa);
    gemm.ose = osa_hcim::macrosim::ose::Ose::with_default_candidates(vec![2, 6, 14, 30, 60])
        .unwrap();
    let mut exec = Executor::new(&graph, gemm);
    exec.collect_bda = true;
    let (imgs, _) = ds.test_batch(0, 4);
    let (_, stats) = exec.forward(imgs, 4).unwrap();
    assert!(!stats.bda_maps.is_empty());
    let mut saw_variation = false;
    for (_, _, _, _, bda) in &stats.bda_maps {
        let min = bda.iter().min().unwrap();
        let max = bda.iter().max().unwrap();
        if min != max {
            saw_variation = true;
        }
    }
    assert!(saw_variation, "every B_D/A map is constant — OSE is blind");
}

#[test]
fn cross_entropy_consistent_with_accuracy() {
    let dir = require_artifacts!();
    let graph = QGraph::load(&dir).unwrap();
    let ds = Dataset::load(&dir).unwrap();
    let n = 32usize.min(ds.test_n());
    let (imgs, labels) = ds.test_batch(0, n);
    let mut exec = Executor::new(&graph, MacroGemm::with_mode(CimMode::Dcim));
    let (logits, _) = exec.forward(imgs, n).unwrap();
    let acc = accuracy(&logits, labels, graph.num_classes);
    let ce = cross_entropy(&logits, labels, graph.num_classes);
    assert!(acc > 0.9 && ce < 0.5, "acc {acc} ce {ce}");
}
