//! Device-model bit-compatibility (DESIGN.md §16):
//!
//! * **baseline parity** — the default `gaussian-thermal` device (no
//!   ADC error, no operation-unit grouping) routes through the exact
//!   pre-device datapath: a `MacroGemm` with an explicitly constructed
//!   baseline device is bit-identical (accumulators, boundary maps,
//!   energy f64s) to one that never heard of the device subsystem, in
//!   every CIM mode, at 1 and 4 threads;
//! * **engine plumbing parity** — spelling the default out through the
//!   config surface (`device_model` + `device_sigma`, the `--device` /
//!   `--device-sigma` flags) changes nothing: logits, energy and
//!   boundary histograms stay bit-identical to the default config at
//!   1 and 4 threads and fleet K in {1, 4};
//! * **variation determinism** — every non-baseline model (and a
//!   non-trivial ADC transfer) is bit-reproducible across thread
//!   counts and fleet sizes, while actually perturbing the logits
//!   relative to the baseline;
//! * **sweep byte-identity** — a repeat `sweep::run` over the same grid
//!   reproduces byte-identical JSON and CSV artifacts.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::device::sweep::{self, EvalSet, SweepGrid};
use osa_hcim::device::{self, DeviceParams};
use osa_hcim::engine::Engine;
use osa_hcim::nn::QGraph;
use osa_hcim::obs::SweepProgress;
use osa_hcim::sched::exec::ExecPool;
use osa_hcim::sched::{GemmEngine, MacroGemm};
use osa_hcim::util::prng::SplitMix64;
use std::sync::Arc;

fn rand_inputs(seed: u64, m: usize, k: usize, n: usize) -> (Vec<i32>, Vec<i32>) {
    let mut g = SplitMix64::new(seed);
    let a = (0..m * k).map(|_| g.next_range_i32(0, 256)).collect();
    let w = (0..n * k).map(|_| g.next_range_i32(-128, 128)).collect();
    (a, w)
}

/// (accumulators, boundary map, energy bits) of one tiled GEMM —
/// `dev` is threaded in when given, otherwise the engine keeps its
/// built-in default device.
fn gemm_bits(
    mode: CimMode,
    threads: usize,
    dev: Option<&str>,
    params: DeviceParams,
) -> (Vec<i32>, Vec<i32>, u64) {
    let (m, k, n) = (67usize, 300usize, 20usize);
    let (a, w) = rand_inputs(0xD15C0, m, k, n);
    let mut e = MacroGemm::with_mode(mode).with_pool(ExecPool::new(threads));
    if let Some(name) = dev {
        e = e.with_device(device::build(name, params).unwrap());
    }
    let r = e.gemm(&a, m, k, &w, n, 7).unwrap();
    (r.out, r.bda, r.account.total_energy_j().to_bits())
}

#[test]
fn explicit_baseline_device_matches_the_implicit_default() {
    // a hand-built gaussian-thermal at the spec sigma with a trivial
    // ADC IS the legacy datapath — same bits in every mode at both
    // thread counts, with no is-this-the-default special casing
    let baseline = DeviceParams { sigma: osa_hcim::spec::SIGMA_CODE, ..DeviceParams::default() };
    for mode in [CimMode::Dcim, CimMode::Hcim, CimMode::Osa, CimMode::Acim] {
        for threads in [1usize, 4] {
            let implicit = gemm_bits(mode, threads, None, baseline);
            let explicit = gemm_bits(mode, threads, Some("gaussian-thermal"), baseline);
            assert_eq!(
                implicit,
                explicit,
                "explicit baseline device shifts {} bits at {threads} threads",
                mode.name()
            );
        }
    }
}

/// (logit bits, energy bits, boundary histogram) of one forward pass
/// over a fixed synthetic batch.
type Fp = (Vec<u32>, u64, [u64; 16]);

fn forward_bits(cfg: SystemConfig, backend: &str, fleet_k: usize, threads: usize) -> Fp {
    let graph = Arc::new(QGraph::synthetic());
    let n = 4usize;
    let mut g = SplitMix64::new(0xF1EE7);
    let images: Vec<u8> = (0..n * 32 * 32 * 3).map(|_| g.next_below(256) as u8).collect();
    let engine = Engine::builder()
        .config(cfg)
        .graph(graph)
        .backend(backend)
        .fleet(fleet_k)
        .threads(threads)
        .build()
        .unwrap();
    let mut exec = engine.executor().unwrap();
    exec.preplan().unwrap();
    let (logits, stats) = exec.forward(&images, n).unwrap();
    (
        logits.iter().map(|x| x.to_bits()).collect(),
        stats.account.total_energy_j().to_bits(),
        stats.b_hist,
    )
}

/// Every (backend, fleet K) lane the acceptance criteria name.
const LANES: [(&str, usize); 3] = [("macro-hybrid", 1), ("macro-fleet", 1), ("macro-fleet", 4)];

#[test]
fn spelled_out_default_device_keeps_engine_bits() {
    // the PR's acceptance bar: `--device gaussian-thermal` (the
    // default, spelled out) must not move a single logit, energy or
    // boundary-histogram bit at any thread count or fleet size
    for (backend, k) in LANES {
        for threads in [1usize, 4] {
            let base = forward_bits(SystemConfig::default(), backend, k, threads);
            let mut cfg = SystemConfig::default();
            cfg.device_model = "gaussian-thermal".to_string();
            cfg.device_sigma = Some(cfg.spec.sigma_code);
            let spelled = forward_bits(cfg, backend, k, threads);
            assert_eq!(
                base,
                spelled,
                "--device gaussian-thermal shifts {backend} K={k} bits at {threads} threads"
            );
        }
    }
}

#[test]
fn variation_models_are_deterministic_and_actually_perturb() {
    // each non-baseline corner: same bits across thread counts and
    // fleet sizes — and different bits from the baseline (a variation
    // model that changes nothing is a silent no-op)
    let corners: [(&str, usize, f64, f64); 4] = [
        ("ideal", 0, 0.0, 1.0),
        ("capacitor-mismatch", 0, 0.0, 1.0),
        ("lognormal-conductance", 0, 0.0, 1.0),
        // baseline noise model, non-trivial ADC: grouped accumulation
        // plus offset/gain error exercises `adc_transfer_dev`
        ("gaussian-thermal", 36, 0.25, 1.02),
    ];
    let baseline = forward_bits(SystemConfig::default(), "macro-hybrid", 1, 1);
    for (model, s_ou, offset, gain) in corners {
        let cfg = || {
            let mut c = SystemConfig::default();
            c.device_model = model.to_string();
            c.device_s_ou = s_ou;
            c.device_adc_offset = offset;
            c.device_adc_gain = gain;
            c
        };
        let reference = forward_bits(cfg(), "macro-hybrid", 1, 1);
        assert_ne!(reference.0, baseline.0, "{model} (s_ou={s_ou}) left every logit untouched");
        for (backend, k) in LANES {
            for threads in [1usize, 4] {
                let got = forward_bits(cfg(), backend, k, threads);
                assert_eq!(
                    got.0,
                    reference.0,
                    "{model} logits drift on {backend} K={k} at {threads} threads"
                );
                assert_eq!(
                    got.2,
                    reference.2,
                    "{model} b_hist drifts on {backend} K={k} at {threads} threads"
                );
            }
        }
    }
}

#[test]
fn sweep_artifacts_are_byte_identical_across_runs() {
    let mut cfg = SystemConfig::default();
    cfg.engine_threads = 2;
    cfg.gov_max_level = 1;
    let graph = Arc::new(QGraph::synthetic());
    let eval = EvalSet::synthetic(&cfg, &graph, 2).unwrap();
    let grid = SweepGrid {
        boundaries: vec![10, 6],
        sigmas: vec![0.0, 0.3],
        mc_seeds: 2,
        images: eval.len(),
        corner_sigma: 0.45,
    };
    let run = || {
        let progress = SweepProgress::new();
        let report = sweep::run(&cfg, &graph, &eval, &grid, &progress).unwrap();
        (report.to_json().to_string_compact(), report.to_csv())
    };
    let (json_a, csv_a) = run();
    let (json_b, csv_b) = run();
    assert_eq!(json_a, json_b, "repeat sweep must reproduce byte-identical JSON");
    assert_eq!(csv_a, csv_b, "repeat sweep must reproduce byte-identical CSV");
    assert!(json_a.contains("\"schema\":1"), "{json_a}");
}
