//! Cross-layer parity: the PJRT artifacts (AOT-lowered from the L1
//! Pallas kernels / L2 JAX model) must agree with the native Rust
//! simulator — bit-exactly where the computation is deterministic.
//!
//! Requires `make artifacts`.  Tests are skipped (not failed) when the
//! artifacts directory is absent so `cargo test` stays green pre-build.

use osa_hcim::config::CimMode;
use osa_hcim::macrosim::MacroUnit;
use osa_hcim::runtime::{PjrtGemm, Runtime};
use osa_hcim::sched::{GemmEngine, MacroGemm};
use osa_hcim::spec::{MacroSpec, TILE_M};
use osa_hcim::util::prng::SplitMix64;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = osa_hcim::spec::default_artifacts_dir();
    dir.join("spec.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

/// Load the PJRT runtime or skip: default builds carry the stub
/// (`pjrt` feature off), whose `load` always errors even when the
/// artifacts exist.
macro_rules! require_runtime {
    ($dir:expr, $with_model:expr) => {
        match Runtime::load($dir, $with_model) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: PJRT runtime unavailable ({e})");
                return;
            }
        }
    };
}

fn rand_tile(seed: u64) -> (Vec<i32>, Vec<i32>, Vec<i32>, Vec<f32>) {
    let sp = MacroSpec::default();
    let mut rng = SplitMix64::new(seed);
    let a: Vec<i32> = (0..TILE_M * sp.cols).map(|_| rng.next_range_i32(0, 256)).collect();
    let w: Vec<i32> = (0..sp.hmus * sp.cols).map(|_| rng.next_range_i32(-128, 128)).collect();
    let b: Vec<i32> = (0..TILE_M).map(|_| rng.next_range_i32(0, 12)).collect();
    let noise = rng.normals_f32(TILE_M * sp.hmus * sp.w_bits, sp.sigma_code);
    (a, w, b, noise)
}

#[test]
fn hybrid_tile_artifact_matches_native_bitexact() {
    let dir = require_artifacts!();
    let rt = require_runtime!(&dir, false);
    let sp = MacroSpec::default();
    for seed in [1u64, 2, 3] {
        let (a, w, b, noise) = rand_tile(seed);
        let pjrt = rt.hybrid_tile(&a, &w, &b, &noise).expect("pjrt exec");
        let unit = MacroUnit::new(&w, sp).unwrap();
        for s in 0..TILE_M {
            let packed = unit.pack_acts(&a[s * sp.cols..(s + 1) * sp.cols]);
            let nslice = &noise[s * sp.hmus * sp.w_bits..(s + 1) * sp.hmus * sp.w_bits];
            let native = unit.compute_hybrid(&packed, b[s], nslice);
            assert_eq!(
                native,
                &pjrt[s * sp.hmus..(s + 1) * sp.hmus],
                "seed {seed} row {s} B={}",
                b[s]
            );
        }
    }
}

#[test]
fn se_tile_artifact_matches_native_bitexact() {
    let dir = require_artifacts!();
    let rt = require_runtime!(&dir, false);
    let sp = MacroSpec::default();
    let (a, w, _, _) = rand_tile(7);
    let pjrt = rt.se_tile(&a, &w).expect("pjrt exec");
    let unit = MacroUnit::new(&w, sp).unwrap();
    for s in 0..TILE_M {
        let packed = unit.pack_acts(&a[s * sp.cols..(s + 1) * sp.cols]);
        assert_eq!(unit.saliency(&packed), pjrt[s], "row {s}");
    }
}

#[test]
fn hybrid_tile_b0_equals_exact_dot() {
    let dir = require_artifacts!();
    let rt = require_runtime!(&dir, false);
    let sp = MacroSpec::default();
    let (a, w, _, noise) = rand_tile(11);
    let b = vec![0i32; TILE_M];
    let pjrt = rt.hybrid_tile(&a, &w, &b, &noise).expect("pjrt exec");
    for s in 0..TILE_M {
        for h in 0..sp.hmus {
            let expect: i32 = (0..sp.cols)
                .map(|c| a[s * sp.cols + c] * w[h * sp.cols + c])
                .sum();
            assert_eq!(pjrt[s * sp.hmus + h], expect, "row {s} hmu {h}");
        }
    }
}

#[test]
fn pjrt_gemm_engine_matches_native_engine() {
    let dir = require_artifacts!();
    let rt = require_runtime!(&dir, false);
    let thresholds = vec![4, 8, 16, 32, 64];
    let (m, k, n) = (64usize, 300usize, 20usize);
    let mut rng = SplitMix64::new(21);
    let a: Vec<i32> = (0..m * k).map(|_| rng.next_range_i32(0, 256)).collect();
    let w: Vec<i32> = (0..n * k).map(|_| rng.next_range_i32(-128, 128)).collect();
    for mode in [CimMode::Dcim, CimMode::Hcim, CimMode::Osa] {
        let mut native = MacroGemm::with_mode(mode);
        native.ose =
            osa_hcim::macrosim::ose::Ose::with_default_candidates(thresholds.clone()).unwrap();
        let mut pjrt = PjrtGemm::new(&rt, mode, thresholds.clone()).unwrap();
        let rn = native.gemm(&a, m, k, &w, n, 2).unwrap();
        let rp = pjrt.gemm(&a, m, k, &w, n, 2).unwrap();
        assert_eq!(rn.out, rp.out, "mode {}", mode.name());
        assert_eq!(rn.bda, rp.bda, "mode {} boundaries", mode.name());
        assert_eq!(rn.b_hist, rp.b_hist, "mode {} hist", mode.name());
        // energy model must agree too
        assert!(
            (rn.account.total_energy_j() - rp.account.total_energy_j()).abs()
                < 1e-9 * rn.account.total_energy_j().max(1e-30),
            "mode {} energy",
            mode.name()
        );
    }
}

#[test]
fn model_artifact_reproduces_golden_float_logits() {
    let dir = require_artifacts!();
    let rt = require_runtime!(&dir, true);
    let ds = osa_hcim::nn::data::Dataset::load(&dir).unwrap();
    let golden = osa_hcim::nn::data::Golden::load(&dir).unwrap();
    let n = 128usize.min(ds.test_n());
    let logits = rt.model_forward_all(&ds.test_x[..n * ds.img_bytes], n, golden.classes).unwrap();
    for (i, (a, b)) in logits.iter().zip(&golden.float_logits[..n * golden.classes]).enumerate()
    {
        assert!(
            (a - b).abs() < 1e-3 + 1e-3 * b.abs(),
            "logit {i}: pjrt {a} vs python {b}"
        );
    }
}

#[test]
fn prng_parity_against_python_golden_vectors() {
    let dir = require_artifacts!();
    let text = std::fs::read_to_string(dir.join("spec.json")).unwrap();
    let doc = osa_hcim::io::json::parse(&text).unwrap();
    let gv = doc.get("prng_golden").expect("prng_golden");
    let seed = u64::from_str_radix(gv.get("seed_hex").unwrap().as_str().unwrap(), 16).unwrap();
    let u64s: Vec<u64> = gv
        .get("u64_hex")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|v| u64::from_str_radix(v.as_str().unwrap(), 16).unwrap())
        .collect();
    let mut g = SplitMix64::new(seed);
    for (i, &expect) in u64s.iter().enumerate() {
        assert_eq!(g.next_u64(), expect, "u64 vector {i}");
    }
    let normals: Vec<f64> = gv
        .get("normal")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    // recompute at f64 (normals_f32 applies sigma and casts)
    let mut g = SplitMix64::new(seed);
    let mut got = Vec::new();
    while got.len() < normals.len() {
        let mut u1 = g.next_f64();
        let u2 = g.next_f64();
        if u1 <= 0.0 {
            u1 = 2.0_f64.powi(-53);
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let t = 2.0 * std::f64::consts::PI * u2;
        got.push(r * t.cos());
        if got.len() < normals.len() {
            got.push(r * t.sin());
        }
    }
    for (i, (a, b)) in got.iter().zip(&normals).enumerate() {
        assert!(
            (a - b).abs() <= 1e-9 * (1.0 + b.abs()),
            "normal vector {i}: rust {a} vs python {b} (libm drift too large)"
        );
    }
}
