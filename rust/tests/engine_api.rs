//! Unified-engine API guarantees (DESIGN.md §12):
//!
//! * public-API smoke — `engine::{Engine, EngineBuilder, BackendRegistry,
//!   InferRequest}` stay exported (the CI contract for downstream users);
//! * backend parity — the same synthetic batch through an `Engine` with
//!   the `macro-hybrid` backend is **bit-identical** (logits AND energy
//!   f64s) to a hand-built `MacroGemm` executor, across 1 and 4 threads;
//! * typed selection errors — unknown backend names list every
//!   registered backend at builder, registry and coordinator level.

// The smoke import IS the test: if any of these stops being exported,
// this file no longer compiles.
use osa_hcim::engine::{
    Backend, BackendKnobs, BackendRegistry, Capabilities, DeviceCaps, Engine, EngineBuilder,
    InferOptions, InferRequest, InferResponse,
};

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::coordinator::Server;
use osa_hcim::nn::{Executor, QGraph};
use osa_hcim::sched::exec::ExecPool;
use osa_hcim::sched::MacroGemm;
use osa_hcim::serve::qos::{SubmitError, Tier};
use osa_hcim::util::prng::SplitMix64;
use std::sync::Arc;

fn synth_batch(n: usize) -> Vec<u8> {
    let mut g = SplitMix64::new(0xBA7C4);
    (0..n * 32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
}

/// The public-API smoke test proper: every re-exported name is usable,
/// not just importable.
#[test]
fn public_api_surface_stays_exported() {
    let _builder: EngineBuilder = Engine::builder();
    let registry: BackendRegistry = BackendRegistry::builtin();
    assert_eq!(
        registry.names(),
        vec!["macro-hybrid", "macro-dcim", "macro-acim", "macro-fleet", "pjrt"]
    );
    let req: InferRequest = InferRequest::new(vec![0u8; 4]).with_tier(Tier::Gold);
    let opts: InferOptions = req.options.clone();
    assert_eq!(opts.tier, Tier::Gold);
    // Backend stays object-safe: a trait object can be named and the
    // caps/knobs types are public
    fn _takes_dyn(_b: &mut dyn Backend) {}
    let _caps: Option<Capabilities> = None;
    let _dev: Option<DeviceCaps> = None;
    let _knobs = BackendKnobs::default();
    let _resp: Option<InferResponse> = None;
}

/// Forward a batch through the engine facade and through a hand-built
/// `MacroGemm` executor on an identically sized pool; both runs must
/// agree to the bit on logits and on the modeled energy (f64).
fn parity_at(threads: usize) -> (Vec<u32>, u64, [u64; 16]) {
    let cfg = SystemConfig::default(); // mode = osa: noise + OSE active
    let graph = Arc::new(QGraph::synthetic());
    let n = 4usize;
    let images = synth_batch(n);

    // facade path
    let engine = Engine::builder()
        .config(cfg.clone())
        .graph(graph.clone())
        .backend("macro-hybrid")
        .threads(threads)
        .build()
        .unwrap();
    let mut exec = engine.executor().unwrap();
    exec.preplan().unwrap();
    let (logits_e, stats_e) = exec.forward(&images, n).unwrap();

    // hand-built path (what `coordinator` wired up before the registry)
    let gemm = MacroGemm::new(
        cfg.mode,
        cfg.spec,
        cfg.fixed_b,
        cfg.thresholds.clone(),
        cfg.noise_seed,
    )
    .unwrap()
    .with_pool(ExecPool::new(threads));
    let mut hand = Executor::new(&graph, gemm);
    hand.preplan().unwrap();
    let (logits_h, stats_h) = hand.forward(&images, n).unwrap();

    let bits_e: Vec<u32> = logits_e.iter().map(|x| x.to_bits()).collect();
    let bits_h: Vec<u32> = logits_h.iter().map(|x| x.to_bits()).collect();
    assert_eq!(bits_e, bits_h, "logit bits diverge at {threads} threads");
    let energy_e = stats_e.account.total_energy_j().to_bits();
    let energy_h = stats_h.account.total_energy_j().to_bits();
    assert_eq!(energy_e, energy_h, "energy f64 bits diverge at {threads} threads");
    assert_eq!(stats_e.b_hist, stats_h.b_hist, "boundary histograms diverge");
    (bits_e, energy_e, stats_e.b_hist)
}

#[test]
fn facade_is_bit_identical_to_hand_built_macro_gemm() {
    let (bits_1, energy_1, hist_1) = parity_at(1);
    let (bits_4, energy_4, hist_4) = parity_at(4);
    // and the thread count itself never shifts results (DESIGN.md §11)
    assert_eq!(bits_1, bits_4, "1-thread vs 4-thread logits diverge");
    assert_eq!(energy_1, energy_4, "1-thread vs 4-thread energy diverges");
    assert_eq!(hist_1, hist_4);
}

#[test]
fn mode_pinned_backends_match_hand_built_modes() {
    // the dcim/acim registry entries are the same datapaths as the
    // hand-built engines, bit for bit
    let graph = Arc::new(QGraph::synthetic());
    let images = synth_batch(2);
    let engine = Engine::builder().graph(graph.clone()).threads(2).build().unwrap();
    for mode in [CimMode::Dcim, CimMode::Acim] {
        let mut facade = Executor::new(&graph, engine.backend_for_mode(mode).unwrap());
        let (lf, sf) = facade.forward(&images, 2).unwrap();
        let mut hand =
            Executor::new(&graph, MacroGemm::with_mode(mode).with_pool(ExecPool::new(2)));
        let (lh, sh) = hand.forward(&images, 2).unwrap();
        assert_eq!(
            lf.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            lh.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "{mode:?} logits diverge"
        );
        assert_eq!(
            sf.account.total_energy_j().to_bits(),
            sh.account.total_energy_j().to_bits(),
            "{mode:?} energy diverges"
        );
    }
}

#[test]
fn builder_error_lists_registered_backends() {
    let err = Engine::builder()
        .graph(Arc::new(QGraph::synthetic()))
        .backend("gpu-macro")
        .build()
        .unwrap_err();
    let msg = format!("{err:#}");
    for name in ["macro-hybrid", "macro-dcim", "macro-acim", "macro-fleet", "pjrt"] {
        assert!(msg.contains(name), "error must list {name}: {msg}");
    }
}

#[test]
fn coordinator_validates_per_request_backend() {
    let mut cfg = SystemConfig::default();
    cfg.mode = CimMode::Dcim;
    cfg.workers = 1;
    let server = Server::start(&cfg, Arc::new(QGraph::synthetic())).unwrap();

    // unknown name: typed error listing the registry, nothing enqueued
    let req = InferRequest {
        image: synth_batch(1),
        options: InferOptions { backend: Some("nope".into()), ..Default::default() },
    };
    match server.submit_request(req) {
        Err(SubmitError::UnknownBackend { requested, registered }) => {
            assert_eq!(requested, "nope");
            assert!(registered.iter().any(|n| n == "macro-hybrid"), "{registered:?}");
        }
        other => panic!("expected UnknownBackend, got {other:?}"),
    }

    // registered-but-unavailable (pjrt without the feature): typed 400 shape
    #[cfg(not(feature = "pjrt"))]
    {
        let req = InferRequest {
            image: synth_batch(1),
            options: InferOptions { backend: Some("pjrt".into()), ..Default::default() },
        };
        match server.submit_request(req) {
            Err(SubmitError::BackendUnavailable { name, .. }) => assert_eq!(name, "pjrt"),
            other => panic!("expected BackendUnavailable, got {other:?}"),
        }
    }

    // out-of-range boundary: typed option error
    let req = InferRequest {
        image: synth_batch(1),
        options: InferOptions { boundary: Some(99), ..Default::default() },
    };
    match server.submit_request(req) {
        Err(SubmitError::InvalidOption { field, .. }) => assert_eq!(field, "boundary"),
        other => panic!("expected InvalidOption, got {other:?}"),
    }

    // a valid per-request backend override is served, tagged with it
    let req = InferRequest {
        image: synth_batch(1),
        options: InferOptions { backend: Some("macro-dcim".into()), ..Default::default() },
    };
    let resp = server.submit_request(req).unwrap().recv().unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.backend, "macro-dcim");
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 1);
    assert_eq!(metrics.errors, 0);
}

#[test]
fn per_request_seed_override_is_deterministic() {
    // OSA mode: analog noise is live, so the seed must matter — and the
    // same seed must reproduce the same bits through the whole serving
    // stack (request grouping, knob re-application, plan cache reuse)
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    let server = Server::start(&cfg, Arc::new(QGraph::synthetic())).unwrap();
    let image = synth_batch(1);
    let logits_for = |seed: Option<u64>| -> Vec<u32> {
        let req = InferRequest {
            image: image.clone(),
            options: InferOptions { noise_seed: seed, ..Default::default() },
        };
        let resp = server.submit_request(req).unwrap().recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        resp.logits.iter().map(|x| x.to_bits()).collect()
    };
    let a1 = logits_for(Some(1));
    let a2 = logits_for(Some(1));
    let b = logits_for(Some(2));
    let default1 = logits_for(None);
    let default2 = logits_for(None);
    assert_eq!(a1, a2, "same seed must be bit-identical");
    assert_ne!(a1, b, "different seeds must shift the noise");
    assert_eq!(default1, default2, "default seed must stay deterministic");
    server.shutdown();
}
