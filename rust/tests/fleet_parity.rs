//! Multi-macro fleet guarantees (DESIGN.md §14):
//!
//! * **K=1 parity** — `macro-fleet` with one macro is bit-identical
//!   (logits AND energy f64s AND boundary histograms) to `macro-hybrid`,
//!   at 1 and 4 threads;
//! * **deterministic reduce** — for a fixed K in {2, 4}, repeat runs and
//!   different thread counts reproduce the same bits, and split-K layers
//!   charge nonzero inter-macro transfer energy;
//! * **pooled weights** — the CIMPool-style pool + index map rebuilds
//!   the exact weight matrix through the public API;
//! * **serve surface** — `GET /v2/topology` and `/metrics` expose the
//!   placement and accounted transfer cost, and placement errors render
//!   the typed `invalid_placement` / `fleet_capacity_exceeded` envelopes.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::SystemConfig;
use osa_hcim::engine::Engine;
use osa_hcim::io::json::{parse, JsonValue};
use osa_hcim::nn::{Op, QConv, QFc, QGraph};
use osa_hcim::sched::fleet::WeightPool;
use osa_hcim::sched::plan::LayerPlan;
use osa_hcim::serve::http;
use osa_hcim::serve::Gateway;
use osa_hcim::spec::MacroSpec;
use osa_hcim::util::prng::SplitMix64;
use std::collections::BTreeMap;
use std::sync::Arc;

fn synth_batch(n: usize) -> Vec<u8> {
    let mut g = SplitMix64::new(0xF1EE7);
    (0..n * 32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
}

/// A `/v2/infer` body: the image plus a raw JSON options object.
fn v2_body(seed: u64, options: &str) -> String {
    let mut g = SplitMix64::new(seed);
    let img: Vec<u8> = (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect();
    let mut body = String::with_capacity(img.len() * 4 + 64);
    body.push_str("{\"image\":[");
    for (i, b) in img.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&b.to_string());
    }
    body.push_str("],\"options\":");
    body.push_str(options);
    body.push('}');
    body
}

/// Synthetic-style two-conv graph whose second conv contracts over
/// k = 3*3*32 = 288 > 144 macro columns — two K-tiles, one more than a
/// `residency_tiles = 1` macro holds, so the fleet planner must split
/// its columns across macros (the stem, k = 27, never splits).
fn split_k_graph() -> QGraph {
    let mut g = SplitMix64::new(0x5711F);
    let mut conv = |name: &str, cin: usize, cout: usize| QConv {
        name: name.into(),
        kh: 3,
        kw: 3,
        cin,
        cout,
        stride: 1,
        act_scale: 1.0 / 255.0,
        w_scale: 0.05,
        w_q: (0..cout * 9 * cin).map(|_| g.next_range_i32(-64, 64)).collect(),
        bias_q: vec![0; cout],
    };
    let stem = conv("stem", 3, 32);
    let deep = conv("deep", 32, 16);
    let fc = QFc {
        cin: 16,
        cout: 10,
        act_scale: 0.05,
        w_scale: 0.05,
        w_q: (0..10 * 16).map(|_| g.next_range_i32(-64, 64)).collect(),
        bias_q: vec![0; 10],
    };
    let mut convs = BTreeMap::new();
    convs.insert("stem".to_string(), stem);
    convs.insert("deep".to_string(), deep);
    QGraph {
        convs,
        fc,
        ops: vec![
            Op::QConv { name: "stem".into(), relu: true },
            Op::QConv { name: "deep".into(), relu: true },
            Op::Gap,
            Op::QFc,
        ],
        num_classes: 10,
    }
}

/// Forward a synthetic batch through the engine facade on `backend`
/// with a one-macro fleet config; single-macro backends ignore the
/// fleet knob, which is exactly what the parity test relies on.
fn forward_bits(backend: &str, threads: usize) -> (Vec<u32>, u64, [u64; 16]) {
    let graph = Arc::new(QGraph::synthetic());
    let n = 4usize;
    let images = synth_batch(n);
    let engine = Engine::builder()
        .config(SystemConfig::default()) // mode = osa: noise + OSE live
        .graph(graph)
        .backend(backend)
        .fleet(1)
        .threads(threads)
        .build()
        .unwrap();
    let mut exec = engine.executor().unwrap();
    exec.preplan().unwrap();
    let (logits, stats) = exec.forward(&images, n).unwrap();
    (
        logits.iter().map(|x| x.to_bits()).collect(),
        stats.account.total_energy_j().to_bits(),
        stats.b_hist,
    )
}

#[test]
fn fleet_of_one_is_bit_identical_to_macro_hybrid() {
    for threads in [1usize, 4] {
        let (lh, eh, hh) = forward_bits("macro-hybrid", threads);
        let (lf, ef, hf) = forward_bits("macro-fleet", threads);
        assert_eq!(lh, lf, "K=1 fleet logits diverge at {threads} threads");
        assert_eq!(eh, ef, "K=1 fleet energy f64 diverges at {threads} threads");
        assert_eq!(hh, hf, "K=1 fleet boundary histogram diverges at {threads} threads");
    }
}

#[test]
fn sharded_reduce_is_deterministic_per_fleet_size() {
    let graph = Arc::new(split_k_graph());
    let images = synth_batch(2);
    for k in [2usize, 4] {
        let run = |threads: usize| -> (Vec<u32>, u64, f64, u64) {
            let mut cfg = SystemConfig::default();
            cfg.fleet_residency_tiles = 1; // force the deep conv to split
            let engine = Engine::builder()
                .config(cfg)
                .graph(graph.clone())
                .backend("macro-fleet")
                .fleet(k)
                .threads(threads)
                .build()
                .unwrap();
            let mut exec = engine.executor().unwrap();
            exec.preplan().unwrap();
            let (logits, stats) = exec.forward(&images, 2).unwrap();
            (
                logits.iter().map(|x| x.to_bits()).collect(),
                stats.account.total_energy_j().to_bits(),
                stats.account.transfer_fj,
                stats.account.transfer_hops,
            )
        };
        let (l_a, e_a, t_a, h_a) = run(1);
        let (l_b, e_b, t_b, h_b) = run(1);
        let (l_c, e_c, t_c, h_c) = run(4);
        assert_eq!(l_a, l_b, "K={k}: repeat run shifts the logits");
        assert_eq!(e_a, e_b, "K={k}: repeat run shifts the energy f64");
        assert_eq!(l_a, l_c, "K={k}: thread count shifts the reduce order");
        assert_eq!(e_a, e_c, "K={k}: thread count shifts the energy merge");
        assert!(t_a > 0.0, "K={k}: split-K must charge transfer energy");
        assert!(h_a > 0, "K={k}: split-K must charge transfer hops");
        assert_eq!(t_a.to_bits(), t_b.to_bits(), "K={k}: transfer energy not repeatable");
        assert_eq!(t_a.to_bits(), t_c.to_bits(), "K={k}: transfer energy thread-dependent");
        assert_eq!(h_a, h_b, "K={k}: hop count not repeatable");
        assert_eq!(h_a, h_c, "K={k}: hop count thread-dependent");
    }
}

#[test]
fn pooled_weights_round_trip_via_public_api() {
    let sp = MacroSpec::default();
    let mut g = SplitMix64::new(0xB00);
    let (n, k) = (12usize, 200usize);
    let w: Vec<i32> = (0..n * k).map(|_| g.next_range_i32(-128, 128)).collect();
    let plan = LayerPlan::build(&w, n, k, 7, sp).unwrap();
    let pool = WeightPool::from_plan(&plan);
    assert_eq!(pool.logical_tiles(), pool.nt * pool.kt);
    assert!(pool.compression() >= 1.0);
    assert_eq!(pool.reconstruct(n, k), w, "pool + index map must rebuild exact weights");
}

#[test]
fn topology_and_metrics_expose_split_k_transfer() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    cfg.backend = "macro-fleet".to_string();
    cfg.fleet_macros = 4;
    cfg.fleet_residency_tiles = 1;
    let gw = Gateway::start(&cfg, Arc::new(split_k_graph()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();

    // the placement is reportable before any traffic
    let (status, body) = http::request(&addr, "GET", "/v2/topology", None).unwrap();
    assert_eq!(status, 200, "{body}");
    let doc = parse(&body).unwrap();
    assert_eq!(doc.get("backend").and_then(JsonValue::as_str), Some("macro-fleet"));
    let fleet = doc.get("fleet").expect("fleet object");
    assert_eq!(fleet.get("macros").and_then(JsonValue::as_i64), Some(4));
    assert_eq!(fleet.get("residency_tiles").and_then(JsonValue::as_i64), Some(1));
    let layers = doc.get("layers").and_then(JsonValue::as_array).unwrap();
    let split: Vec<bool> = layers
        .iter()
        .map(|l| l.get("split_k").and_then(JsonValue::as_bool).unwrap())
        .collect();
    assert_eq!(split, vec![false, true], "deep conv (k=288 > 144 cols) must split: {body}");
    let residency = doc.get("macro_residency").and_then(JsonValue::as_array).unwrap();
    assert_eq!(residency.len(), 4);

    // serve one image so transfer cost lands in the live account
    let body = v2_body(1, "{}");
    let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");

    let (_, body) = http::request(&addr, "GET", "/v2/topology", None).unwrap();
    let doc = parse(&body).unwrap();
    let transfer = doc.get("transfer").expect("transfer object");
    assert!(
        transfer.get("energy_fj").and_then(JsonValue::as_f64).unwrap() > 0.0,
        "split-K serving must account transfer energy: {body}"
    );
    assert!(transfer.get("hops").and_then(JsonValue::as_f64).unwrap() > 0.0);

    let (_, body) = http::request(&addr, "GET", "/metrics", None).unwrap();
    let doc = parse(&body).unwrap();
    let fleet = doc.get("fleet").expect("fleet object in /metrics");
    assert!(fleet.get("transfer_energy_fj").and_then(JsonValue::as_f64).unwrap() > 0.0, "{body}");
    assert!(fleet.get("transfer_fraction").and_then(JsonValue::as_f64).unwrap() > 0.0);
    gw.shutdown();
}

#[test]
fn placement_errors_render_typed_envelopes() {
    let mut cfg = SystemConfig::default();
    cfg.workers = 1;
    cfg.backend = "macro-fleet".to_string();
    cfg.fleet_macros = 2;
    cfg.fleet_residency_tiles = 1;
    let gw = Gateway::start(&cfg, Arc::new(split_k_graph()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();
    let err_field = |doc: &JsonValue, f: &str| -> Option<String> {
        doc.get("error").and_then(|e| e.get(f)).and_then(JsonValue::as_str).map(String::from)
    };

    // unknown placement mode: typed 400
    let body = v2_body(1, "{\"placement\":\"everywhere\"}");
    let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
    assert_eq!(status, 400, "{resp}");
    let doc = parse(&resp).unwrap();
    assert_eq!(err_field(&doc, "code").as_deref(), Some("invalid_placement"));
    assert!(err_field(&doc, "message").unwrap().contains("everywhere"), "{resp}");

    // resident placement cannot hold 8 raw tiles (stem 4x1 + deep 2x2)
    // on a 2-macro x 1-tile fleet: 409 with the numbers a client needs
    // to re-plan
    let body = v2_body(1, "{\"placement\":\"resident\"}");
    let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
    assert_eq!(status, 409, "{resp}");
    let doc = parse(&resp).unwrap();
    assert_eq!(err_field(&doc, "code").as_deref(), Some("fleet_capacity_exceeded"));
    let int_field = |f: &str| {
        doc.get("error").and_then(|e| e.get(f)).and_then(JsonValue::as_i64).unwrap()
    };
    assert_eq!(int_field("required_tiles"), 8, "{resp}");
    assert_eq!(int_field("capacity_tiles"), 2, "{resp}");

    // auto placement pools/wraps the same model and still serves
    let body = v2_body(2, "{\"placement\":\"auto\"}");
    let (status, resp) = http::request(&addr, "POST", "/v2/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let metrics = gw.shutdown();
    assert_eq!(metrics.requests, 1, "rejected placements must never reach a worker");
}
